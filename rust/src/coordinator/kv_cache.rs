//! Prefix-sharing paged KV-cache block manager.
//!
//! vLLM-style logical paging, extended with content-addressed prefix
//! reuse: cache capacity is tracked in fixed-size token blocks, and every
//! **full prompt block** is keyed by a *rolling hash chain* — block `i`'s
//! key is `H(key(i-1), tokens[i*bs .. (i+1)*bs])` — so a key identifies
//! not just a block's content but the entire prefix leading to it. On
//! [`BlockManager::admit`] the chain is matched block-by-block against
//! live *and* recently-freed blocks; every match attaches by refcount
//! increment instead of allocating, which is what turns a shared system
//! prompt into one physical prefix serving a whole fan-out of requests.
//!
//! Production chat traffic is dominated by exactly that shape (system
//! prompts, few-shot templates), and prefix reuse is what pushes decode
//! into the long-`L_K`, low-head-count regime where the paper's
//! sequence-aware split policy wins: a request that reuses a long prefix
//! starts decoding at the *full* shared `L_K` from its first token.
//!
//! The sharing rules (DESIGN.md §Prefix sharing):
//!
//! * **Hash-chain rule** — only full blocks of the *prompt* are hashed;
//!   a block's key covers the whole prefix through it, so matching is
//!   consecutive from block 0 and a single diverging token ends the
//!   match. Content is verified on every hash hit (collisions can alias
//!   keys, never blocks).
//! * **Copy-on-write invariant** — a partial prompt tail may share a
//!   donor's full block when the tail equals the donor block's first
//!   tokens (same chain position). The first decode write lands inside
//!   that block, so admission reserves a private *spare* up front and
//!   [`BlockManager::cow_fork`] moves the sequence onto it at the first
//!   generated token, copying the tail. A shared block is **never
//!   mutated**: forks copy, refcounts gate, and the donor's content is
//!   byte-identical before and after (property-tested in
//!   `rust/tests/prefix_cache.rs`).
//! * **Eviction policy** — releasing a sequence decrements refcounts;
//!   blocks that drop to zero *and* carry a hash move to an LRU
//!   evictable list (deepest chain first, so prefix roots outlive their
//!   leaves) instead of the plain free pool. They still count as free
//!   capacity — a fresh allocation recycles the LRU victim and drops its
//!   hash — but until recycled they match new prompts and revive with a
//!   refcount, which is how "recently-freed" prefixes keep their hits.
//!
//! The *physical* cache remains the dense per-bucket tensor the AOT
//! artifacts are compiled with (static shapes — the CUDA-Graph analog),
//! so the block manager governs admission, capacity accounting, sharing,
//! and slot assignment rather than physical page indirection; the
//! invariants (no over-allocation, no leaked or double-freed block, no
//! refcount skew, COW immutability) are vLLM's and are property-tested
//! in `rust/tests/`. Setting
//! [`BlockManagerConfig::enable_prefix_sharing`] to `false` restores the
//! pre-sharing allocator exactly (no hashing, no content retention) —
//! the byte-identity baseline the `prefix_cache` bench gates against.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::request::RequestId;

/// Index of a block in the manager's slab.
pub type BlockId = usize;

/// Block-manager configuration.
#[derive(Debug, Clone)]
pub struct BlockManagerConfig {
    /// Tokens per block (vLLM default 16).
    pub block_size: usize,
    /// Total block budget across all sequences.
    pub num_blocks: usize,
    /// Hard per-sequence token cap (the artifacts' max_seq).
    pub max_seq: usize,
    /// Content-hash full prompt blocks and share them across requests
    /// (refcounted, copy-on-write). `false` restores the pre-sharing
    /// allocator byte-for-byte: every admission allocates fresh blocks
    /// and no content is retained.
    pub enable_prefix_sharing: bool,
}

impl Default for BlockManagerConfig {
    fn default() -> Self {
        // 4096 blocks x 16 tokens = 64k tokens of KV budget.
        BlockManagerConfig {
            block_size: 16,
            num_blocks: 4096,
            max_seq: 1024,
            enable_prefix_sharing: true,
        }
    }
}

/// Prefix-cache counters ([`BlockManager::prefix_stats`]; mirrored into
/// `EngineMetrics` so serving surfaces export hit-rate and blocks saved).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Full prompt blocks probed across all admissions.
    pub lookups: usize,
    /// Of those, blocks served by an existing block (refcount reuse).
    pub hits: usize,
    /// Partial-tail matches that armed a copy-on-write share.
    pub tail_hits: usize,
    /// Prompt tokens whose prefill was skipped because their KV already
    /// existed (full-block hits × block_size + matched tail lengths).
    pub tokens_cached: usize,
    /// Hits served from the evictable list (a freed prefix revived).
    pub revived: usize,
    /// Hashed blocks recycled (hash dropped) to satisfy fresh demand.
    pub evictions: usize,
    /// Copy-on-write forks performed at first divergent write.
    pub cow_forks: usize,
}

impl PrefixCacheStats {
    /// Fraction of probed full prompt blocks served by sharing.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups as f64
    }

    /// Block allocations avoided by sharing. Exactly the full-block hit
    /// count — a tail share still reserves its fork spare, so it saves
    /// prefill tokens, not blocks. Derived (not stored) so the two
    /// counters cannot skew.
    pub fn blocks_saved(&self) -> usize {
        self.hits
    }
}

/// What [`BlockManager::probe`] learned about a prompt without mutating
/// anything — the read-only half of admission's sharing-aware checks.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixProbe {
    /// Leading full prompt blocks an admission would share.
    pub matched_blocks: usize,
    /// Blocks the admission would *attach* that currently sit on the
    /// evictable list — matched full blocks **and** the COW tail donor.
    /// Attaching revives them, which removes them from spare capacity
    /// without satisfying any of the request's new-block demand, so
    /// admission subtracts this from the available pool.
    pub matched_evictable: usize,
    /// Whether the partial prompt tail would arm a copy-on-write share.
    pub tail_match: bool,
    /// Prompt tokens whose prefill the match would skip.
    pub cached_tokens: usize,
}

/// What an admission granted ([`BlockManager::admit`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmitGrant {
    /// Prompt tokens whose KV already exists — prefill skips them.
    pub cached_tokens: usize,
    /// Full prompt blocks attached by refcount instead of allocation.
    pub shared_blocks: usize,
    /// Blocks newly allocated (including a COW spare, when armed).
    pub new_blocks: usize,
    /// Whether a copy-on-write tail share is pending its first write.
    pub cow_pending: bool,
}

/// Chain-hash seed (arbitrary odd constant).
const HASH_SEED: u64 = 0x51f1_5eed_c0de_b10c;

/// One splitmix64-style mixing step.
fn mix(h: u64, v: u64) -> u64 {
    let mut z = h ^ v.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Extend the rolling chain over one block's tokens. The chain key of
/// block `i` therefore commits to every token in blocks `0..=i`.
fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    let mut h = mix(prev, tokens.len() as u64);
    for &t in tokens {
        h = mix(h, t as u64);
    }
    h
}

/// One physical block's bookkeeping.
#[derive(Debug, Clone, Default)]
struct Block {
    /// Sequences holding a reference. 0 = free or evictable.
    refcount: usize,
    /// Chain key when this is a hashed full prompt block.
    hash: Option<u64>,
    /// Chain key *before* this block (tail-candidate lookup).
    prev_hash: u64,
    /// Retained content: full prompt tokens for hashed blocks, the
    /// copied tail for COW forks. Empty for plain generation blocks.
    tokens: Vec<i32>,
}

/// A pending copy-on-write tail share.
#[derive(Debug, Clone, Copy)]
struct CowPair {
    /// The donor's full block the tail currently reads from.
    shared: BlockId,
    /// The private block reserved for the fork.
    spare: BlockId,
    /// How many of the donor block's tokens this sequence's prompt uses.
    tail_len: usize,
}

/// Per-sequence allocation state.
#[derive(Debug, Clone)]
struct SeqAlloc {
    /// Worst-case token reservation (prompt + max_new).
    tokens: usize,
    /// Prompt tokens served from shared KV.
    cached_tokens: usize,
    /// Every block this sequence holds a reference on (shared prefix,
    /// COW pair, then private blocks).
    attached: Vec<BlockId>,
    /// Pending tail fork, if the admission armed one.
    cow: Option<CowPair>,
}

/// The block manager.
#[derive(Debug)]
pub struct BlockManager {
    cfg: BlockManagerConfig,
    blocks: Vec<Block>,
    /// Plain free pool (unhashed, content-free). LIFO.
    free: Vec<BlockId>,
    /// Refcount-zero blocks still carrying a hash, oldest first —
    /// matchable until recycled, recycled front-first. A plain Vec with
    /// O(n) front-removal and revival scans: both run only on the
    /// admission/release path (never the per-token step loop), and n is
    /// bounded by the block budget. Swap for a VecDeque + per-block
    /// position index if admission ever shows up in a profile.
    evictable: Vec<BlockId>,
    /// Chain key → hashed block (first writer wins; content is verified
    /// on every hit, so a colliding key can never alias content).
    by_hash: HashMap<u64, BlockId>,
    /// Chain key *before* a block → that block (partial-tail candidate
    /// lookup; first writer wins).
    by_prev: HashMap<u64, BlockId>,
    seqs: HashMap<RequestId, SeqAlloc>,
    stats: PrefixCacheStats,
}

impl BlockManager {
    /// Build a manager with every block free.
    pub fn new(cfg: BlockManagerConfig) -> BlockManager {
        assert!(cfg.block_size > 0 && cfg.num_blocks > 0);
        BlockManager {
            blocks: vec![Block::default(); cfg.num_blocks],
            // Reversed so allocation hands out 0, 1, 2, … (stable,
            // deterministic ids — fleet runs replay exactly).
            free: (0..cfg.num_blocks).rev().collect(),
            evictable: Vec::new(),
            by_hash: HashMap::new(),
            by_prev: HashMap::new(),
            seqs: HashMap::new(),
            stats: PrefixCacheStats::default(),
            cfg,
        }
    }

    /// The configuration this manager was built with.
    pub fn config(&self) -> &BlockManagerConfig {
        &self.cfg
    }

    /// Blocks available to fresh allocations: the plain free pool plus
    /// the evictable list (recycling an evictable block only costs its
    /// future match potential).
    pub fn free_blocks(&self) -> usize {
        self.free.len() + self.evictable.len()
    }

    /// Blocks held by live sequences (refcount ≥ 1, counted once each
    /// however many sequences share them).
    pub fn used_blocks(&self) -> usize {
        self.cfg.num_blocks - self.free_blocks()
    }

    /// Blocks on the evictable list (freed but still matchable).
    pub fn evictable_blocks(&self) -> usize {
        self.evictable.len()
    }

    /// Live sequences.
    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Prefix-cache counters since construction.
    pub fn prefix_stats(&self) -> PrefixCacheStats {
        self.stats
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.block_size)
    }

    // ------------------------------------------------------------------
    // Probing (read-only)
    // ------------------------------------------------------------------

    /// Walk the prompt's hash chain against the current block index
    /// without mutating anything: how many leading full blocks (and
    /// whether the partial tail) an admission right now would share.
    pub fn probe(&self, prompt: &[i32]) -> PrefixProbe {
        let mut p = PrefixProbe::default();
        if !self.cfg.enable_prefix_sharing || prompt.is_empty() {
            return p;
        }
        let bs = self.cfg.block_size;
        let n_full = prompt.len() / bs;
        let mut h = HASH_SEED;
        for i in 0..n_full {
            let chunk = &prompt[i * bs..(i + 1) * bs];
            let key = chain_hash(h, chunk);
            let Some(&bid) = self.by_hash.get(&key) else { break };
            if self.blocks[bid].tokens != chunk {
                break; // 64-bit collision: never alias content.
            }
            p.matched_blocks += 1;
            if self.blocks[bid].refcount == 0 {
                p.matched_evictable += 1;
            }
            h = key;
        }
        let tail_len = prompt.len() % bs;
        if p.matched_blocks == n_full && tail_len > 0 {
            if let Some(&cand) = self.by_prev.get(&h) {
                let b = &self.blocks[cand];
                if b.hash.is_some() && b.tokens.len() == bs
                    && b.tokens[..tail_len] == prompt[n_full * bs..]
                {
                    p.tail_match = true;
                    // An evictable donor leaves the spare pool when the
                    // admission attaches it — charge it like an
                    // evictable full-block match, or `can_admit_prompt`
                    // could approve an admission whose spare allocation
                    // then finds both pools empty.
                    if b.refcount == 0 {
                        p.matched_evictable += 1;
                    }
                }
            }
        }
        p.cached_tokens = p.matched_blocks * bs + if p.tail_match { tail_len } else { 0 };
        p
    }

    /// Fresh blocks an admission of this prompt would allocate. A tail
    /// match saves no blocks (its COW spare is reserved up front), only
    /// prefill tokens.
    fn new_blocks_needed(&self, probe: &PrefixProbe, total_tokens: usize) -> usize {
        self.blocks_for(total_tokens) - probe.matched_blocks
    }

    // ------------------------------------------------------------------
    // Admission checks
    // ------------------------------------------------------------------

    /// Prefix-blind worst-case admission check: can a request with
    /// `prompt_len + max_new` tokens be admitted now assuming *nothing*
    /// is shared? (The conservative bound surfaces that want a
    /// content-free answer — e.g. generic capacity gauges — still use.)
    pub fn can_admit(&self, prompt_len: usize, max_new: usize) -> bool {
        let total = prompt_len + max_new;
        total <= self.cfg.max_seq && self.blocks_for(total) <= self.free_blocks()
    }

    /// Sharing-aware admission check: charges only the blocks the prompt
    /// would *not* share. This is the predicate admission pairs with
    /// [`BlockManager::admit`] — both sides run the same probe, so a
    /// passing check cannot be followed by a failing admit.
    pub fn can_admit_prompt(&self, prompt: &[i32], max_new: usize) -> bool {
        let total = prompt.len() + max_new;
        if total > self.cfg.max_seq {
            return false;
        }
        let probe = self.probe(prompt);
        // Matched evictable blocks are revived, not allocated, but they
        // leave the spare pool: both sides of the ledger move.
        let available = self.free_blocks() - probe.matched_evictable;
        self.new_blocks_needed(&probe, total) <= available
    }

    /// Could this request be admitted on an *empty* manager? False means
    /// it can never run here (too long for `max_seq` or bigger than the
    /// whole block budget) — the admission controller rejects such
    /// requests at submission instead of letting them wedge a queue head
    /// forever.
    ///
    /// Deliberately **prefix-blind**: sharing reduces a request's *new*
    /// allocations, but the shared blocks themselves still occupy the
    /// budget, so a request's best-case resident footprint is
    /// `blocks_for(prompt + max_new)` with or without sharing — reuse
    /// multiplies *concurrency*, never single-request capacity. A
    /// sharing-aware "ever" bound would admit requests whose donors can
    /// later be evicted, deadlocking the FIFO head (DESIGN.md §Prefix
    /// sharing).
    pub fn can_ever_admit(&self, prompt_len: usize, max_new: usize) -> bool {
        let total = prompt_len + max_new;
        total <= self.cfg.max_seq && self.blocks_for(total) <= self.cfg.num_blocks
    }

    // ------------------------------------------------------------------
    // Admission / release / fork
    // ------------------------------------------------------------------

    /// Reserve blocks for a new sequence, sharing every full prompt
    /// block the hash chain matches (and arming a copy-on-write tail
    /// share when the partial tail matches a donor block). Worst-case
    /// reservation: the whole `prompt + max_new` footprint — including
    /// the COW fork spare — is allocated or attached up front, vLLM's
    /// conservative admission that avoids mid-generation eviction.
    pub fn admit(&mut self, id: RequestId, prompt: &[i32], max_new: usize) -> Result<AdmitGrant> {
        if self.seqs.contains_key(&id) {
            bail!("sequence {id} already admitted");
        }
        let total = prompt.len() + max_new;
        if total > self.cfg.max_seq {
            bail!("sequence {id}: {total} tokens exceeds max_seq {}", self.cfg.max_seq);
        }
        let probe = self.probe(prompt);
        let need = self.new_blocks_needed(&probe, total);
        if need > self.free_blocks() - probe.matched_evictable {
            bail!(
                "sequence {id}: needs {need} new blocks, only {} free ({} shared)",
                self.free_blocks() - probe.matched_evictable,
                probe.matched_blocks
            );
        }

        let bs = self.cfg.block_size;
        let n_full = prompt.len() / bs;
        let tail_len = prompt.len() % bs;
        let sharing = self.cfg.enable_prefix_sharing;
        if sharing {
            self.stats.lookups += n_full;
            self.stats.hits += probe.matched_blocks;
            self.stats.tokens_cached += probe.cached_tokens;
        }

        let mut attached = Vec::with_capacity(need + probe.matched_blocks + 1);
        let mut chain = HASH_SEED;

        // 1. Attach the matched shared prefix (reviving evictable hits).
        for i in 0..probe.matched_blocks {
            let chunk = &prompt[i * bs..(i + 1) * bs];
            chain = chain_hash(chain, chunk);
            let bid = *self.by_hash.get(&chain).expect("probe matched this key");
            self.attach(bid);
            attached.push(bid);
        }

        // 2. Arm the copy-on-write tail share, spare reserved up front.
        let mut cow = None;
        if probe.tail_match {
            let donor = *self.by_prev.get(&chain).expect("probe matched this tail");
            self.attach(donor);
            attached.push(donor);
            let spare = self.alloc_block()?;
            attached.push(spare);
            cow = Some(CowPair { shared: donor, spare, tail_len });
            self.stats.tail_hits += 1;
        }

        // 3. Allocate the rest: unmatched full prompt blocks are hashed
        //    and indexed immediately (content is known), so later
        //    arrivals can share a *live* sequence's prefix; the partial
        //    tail (when not COW-shared) and generation blocks stay
        //    anonymous.
        let already = attached.len() - if cow.is_some() { 1 } else { 0 }; // chain positions covered
        for pos in already..self.blocks_for(total) {
            let bid = self.alloc_block()?;
            attached.push(bid);
            if sharing && pos < n_full {
                let chunk = &prompt[pos * bs..(pos + 1) * bs];
                let prev = chain;
                chain = chain_hash(chain, chunk);
                let b = &mut self.blocks[bid];
                b.hash = Some(chain);
                b.prev_hash = prev;
                b.tokens.clear();
                b.tokens.extend_from_slice(chunk);
                self.by_hash.entry(chain).or_insert(bid);
                self.by_prev.entry(prev).or_insert(bid);
            }
        }

        let grant = AdmitGrant {
            cached_tokens: probe.cached_tokens,
            shared_blocks: probe.matched_blocks,
            new_blocks: need,
            cow_pending: cow.is_some(),
        };
        self.seqs.insert(
            id,
            SeqAlloc { tokens: total, cached_tokens: probe.cached_tokens, attached, cow },
        );
        Ok(grant)
    }

    /// Perform the pending copy-on-write fork for a sequence, if one was
    /// armed at admission: the tail moves onto its reserved spare (tail
    /// tokens copied), the donor's reference is dropped, and the donor
    /// block is **not** touched. The engine calls this at the sequence's
    /// first generated token — the first write that would land inside
    /// the shared block. Returns whether a fork happened.
    pub fn cow_fork(&mut self, id: RequestId) -> Result<bool> {
        let Some(alloc) = self.seqs.get_mut(&id) else {
            bail!("cow_fork for unknown sequence {id}");
        };
        let Some(CowPair { shared, spare, tail_len }) = alloc.cow.take() else {
            return Ok(false);
        };
        // Drop the donor reference from the attachment list (one entry).
        let pos = alloc
            .attached
            .iter()
            .position(|&b| b == shared)
            .expect("armed COW donor is attached");
        alloc.attached.remove(pos);
        // Copy, never mutate: the donor keeps its content and hash.
        let tail: Vec<i32> = self.blocks[shared].tokens[..tail_len].to_vec();
        self.blocks[spare].tokens = tail;
        self.deref_block(shared);
        self.stats.cow_forks += 1;
        Ok(true)
    }

    /// Release a finished sequence's references. Private blocks return
    /// to the free pool; hashed prompt blocks whose refcount drops to
    /// zero join the evictable list instead (deepest chain first, so
    /// prefix roots are the last recycled) and keep matching until a
    /// fresh allocation recycles them.
    pub fn release(&mut self, id: RequestId) -> Result<()> {
        let Some(alloc) = self.seqs.remove(&id) else {
            bail!("release of unknown sequence {id}");
        };
        // Reverse order: leaves hit the evictable list before their
        // roots, so LRU recycling consumes chains leaf-first.
        for &bid in alloc.attached.iter().rev() {
            self.deref_block(bid);
        }
        Ok(())
    }

    /// Tokens reserved for a sequence (diagnostics).
    pub fn reserved_tokens(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|a| a.tokens)
    }

    /// Prompt tokens a sequence's admission served from shared KV.
    pub fn cached_tokens(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|a| a.cached_tokens)
    }

    /// Blocks a sequence currently holds references on (shared prefix +
    /// COW pair + private). Victim selection's final tie-break: among
    /// equal-priority, equally-fresh candidates, preempting the largest
    /// holder frees the most budget per eviction. Also the size of a
    /// swap transfer for the host-transfer ledger.
    pub fn blocks_held(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|a| a.attached.len())
    }

    /// Materialize a prefix's full blocks as **evictable** cache entries
    /// without admitting a sequence — the receiving half of a cross-pool
    /// KV handoff. Each imported block is hashed, indexed, and parked at
    /// refcount 0, so the continuation's admission revives it as an
    /// ordinary prefix hit (charging zero prefill for those tokens) while
    /// a fleet under pressure can still recycle it like any other
    /// evictable block — an import can therefore never wedge capacity.
    ///
    /// Returns how many blocks were *newly* materialized. Blocks already
    /// resident (live or evictable) are skipped and the walk continues.
    /// Imports are opportunistic: they draw only on the plain free pool
    /// and never evict resident cache state (recycling evictable entries
    /// to make room for an import could churn out exactly the prefixes
    /// live sessions are about to revive — or, for an oversized import,
    /// its own just-written chain root). An exhausted free pool stops
    /// the import early; the un-imported tail simply re-prefills on the
    /// decode side (the recompute fallback), which costs time, never
    /// correctness.
    pub fn import_prefix(&mut self, tokens: &[i32]) -> usize {
        if !self.cfg.enable_prefix_sharing {
            return 0;
        }
        let bs = self.cfg.block_size;
        let mut imported = 0;
        let mut chain = HASH_SEED;
        for chunk in tokens.chunks_exact(bs) {
            let prev = chain;
            chain = chain_hash(chain, chunk);
            if let Some(&bid) = self.by_hash.get(&chain) {
                if self.blocks[bid].tokens == chunk {
                    continue; // already resident — keep walking the chain
                }
                break; // 64-bit collision: never alias content
            }
            if self.free.is_empty() {
                break; // never evict to import — see the doc comment
            }
            let bid = self.alloc_block().expect("free pool is non-empty");
            let b = &mut self.blocks[bid];
            b.hash = Some(chain);
            b.prev_hash = prev;
            b.tokens.clear();
            b.tokens.extend_from_slice(chunk);
            self.by_hash.entry(chain).or_insert(bid);
            self.by_prev.entry(prev).or_insert(bid);
            // Drop the allocation reference: hashed + refcount 0 parks
            // the block on the evictable list, where probe/admit find it.
            self.deref_block(bid);
            imported += 1;
        }
        imported
    }

    // ------------------------------------------------------------------
    // Internals
    // ------------------------------------------------------------------

    /// Take a reference on a block, reviving it from the evictable list
    /// when it was freed-but-still-hashed.
    fn attach(&mut self, bid: BlockId) {
        if self.blocks[bid].refcount == 0 {
            let pos = self
                .evictable
                .iter()
                .position(|&b| b == bid)
                .expect("refcount-0 hashed block must be evictable");
            self.evictable.remove(pos);
            self.stats.revived += 1;
        }
        self.blocks[bid].refcount += 1;
    }

    /// Drop a reference; at zero the block parks on the evictable list
    /// (hashed) or returns to the free pool (anonymous).
    fn deref_block(&mut self, bid: BlockId) {
        let b = &mut self.blocks[bid];
        debug_assert!(b.refcount > 0, "deref of unreferenced block {bid}");
        b.refcount -= 1;
        if b.refcount == 0 {
            if b.hash.is_some() {
                self.evictable.push(bid);
            } else {
                b.tokens.clear();
                self.free.push(bid);
            }
        }
    }

    /// Hand out a fresh block: plain free pool first, then recycle the
    /// LRU evictable block (dropping its hash and index entries).
    fn alloc_block(&mut self) -> Result<BlockId> {
        if let Some(bid) = self.free.pop() {
            self.blocks[bid].refcount = 1;
            return Ok(bid);
        }
        if !self.evictable.is_empty() {
            let bid = self.evictable.remove(0);
            self.unhash(bid);
            self.stats.evictions += 1;
            self.blocks[bid].refcount = 1;
            return Ok(bid);
        }
        bail!("no free blocks");
    }

    /// Strip a block's identity: hash, index entries, retained content.
    fn unhash(&mut self, bid: BlockId) {
        let (hash, prev) = {
            let b = &mut self.blocks[bid];
            (b.hash.take(), b.prev_hash)
        };
        if let Some(h) = hash {
            if self.by_hash.get(&h) == Some(&bid) {
                self.by_hash.remove(&h);
            }
        }
        if self.by_prev.get(&prev) == Some(&bid) {
            self.by_prev.remove(&prev);
            // Re-point the entry at a hashed sibling holding the same
            // chain position, if one exists (diverging continuations of
            // one prefix share `prev`): first-writer-wins would
            // otherwise orphan that position's tail matches for as long
            // as the sibling stays resident. O(blocks), but only on the
            // eviction path, which is already O(blocks).
            let sibling = (0..self.blocks.len()).find(|&i| {
                i != bid && self.blocks[i].hash.is_some() && self.blocks[i].prev_hash == prev
            });
            if let Some(sib) = sibling {
                self.by_prev.insert(prev, sib);
            }
        }
        let b = &mut self.blocks[bid];
        b.prev_hash = 0;
        b.tokens.clear();
    }

    /// Invariant check used by the property tests:
    ///
    /// * every block is in exactly one state — free (unhashed, rc 0),
    ///   evictable (hashed, rc 0), or active (rc ≥ 1);
    /// * free + evictable + active == total;
    /// * Σ refcounts == Σ per-sequence attachments (no leak, no double
    ///   count);
    /// * every sequence holds exactly its worst-case block footprint
    ///   (plus its COW spare while the fork is pending);
    /// * the hash index points only at blocks carrying that hash.
    pub fn check_invariants(&self) -> Result<()> {
        let mut membership = vec![0usize; self.cfg.num_blocks]; // bitset: 1=free, 2=evictable
        for &b in &self.free {
            membership[b] += 1;
            if self.blocks[b].refcount != 0 || self.blocks[b].hash.is_some() {
                bail!("free block {b} has refcount/hash");
            }
        }
        for &b in &self.evictable {
            membership[b] += 2;
            if self.blocks[b].refcount != 0 || self.blocks[b].hash.is_none() {
                bail!("evictable block {b} must be refcount-0 and hashed");
            }
        }
        let mut active = 0usize;
        for (i, b) in self.blocks.iter().enumerate() {
            match (b.refcount, membership[i]) {
                (0, 1) | (0, 2) => {}
                (r, 0) if r >= 1 => active += 1,
                (r, m) => bail!("block {i}: refcount {r} with pool membership {m}"),
            }
        }
        if active + self.free.len() + self.evictable.len() != self.cfg.num_blocks {
            bail!(
                "block accounting broken: {} active + {} free + {} evictable != {}",
                active,
                self.free.len(),
                self.evictable.len(),
                self.cfg.num_blocks
            );
        }
        let refs: usize = self.blocks.iter().map(|b| b.refcount).sum();
        let attachments: usize = self.seqs.values().map(|a| a.attached.len()).sum();
        if refs != attachments {
            bail!("refcount skew: {refs} references vs {attachments} attachments");
        }
        for (id, a) in &self.seqs {
            let want = self.blocks_for(a.tokens) + usize::from(a.cow.is_some());
            if a.attached.len() != want {
                bail!(
                    "sequence {id}: {} tokens want {want} attachments, holds {}",
                    a.tokens,
                    a.attached.len()
                );
            }
        }
        for (h, &b) in &self.by_hash {
            if self.blocks[b].hash != Some(*h) {
                bail!("hash index entry {h:#x} points at block {b} without that hash");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(blocks: usize) -> BlockManager {
        BlockManager::new(BlockManagerConfig {
            block_size: 16,
            num_blocks: blocks,
            max_seq: 1024,
            enable_prefix_sharing: true,
        })
    }

    /// A prompt whose content is unique to `tag` (no accidental sharing).
    fn prompt(tag: i32, len: usize) -> Vec<i32> {
        (0..len).map(|i| tag * 10_000 + i as i32).collect()
    }

    #[test]
    fn admit_reserves_worst_case() {
        let mut m = mgr(10);
        // 100 prompt + 28 new = 128 tokens = 8 blocks.
        let p = prompt(1, 100);
        assert!(m.can_admit(100, 28));
        assert!(m.can_admit_prompt(&p, 28));
        let g = m.admit(1, &p, 28).unwrap();
        assert_eq!(g.new_blocks, 8);
        assert_eq!(g.shared_blocks, 0);
        assert_eq!(m.free_blocks(), 2);
        assert_eq!(m.reserved_tokens(1), Some(128));
        m.check_invariants().unwrap();
    }

    #[test]
    fn admission_denied_when_full() {
        let mut m = mgr(4);
        m.admit(1, &prompt(1, 48), 16).unwrap(); // 64 tokens = 4 blocks
        assert_eq!(m.free_blocks(), 0);
        assert!(!m.can_admit(1, 0));
        assert!(m.admit(2, &prompt(2, 1), 0).is_err());
        m.release(1).unwrap();
        assert!(m.can_admit(1, 0));
        m.check_invariants().unwrap();
    }

    #[test]
    fn max_seq_enforced() {
        let mut m = mgr(1000);
        assert!(!m.can_admit(1000, 100));
        assert!(m.admit(1, &prompt(1, 1000), 100).is_err());
        assert!(m.can_admit(1000, 24));
    }

    #[test]
    fn can_ever_admit_ignores_current_occupancy() {
        let mut m = mgr(4); // 64-token budget
        m.admit(1, &prompt(1, 48), 16).unwrap(); // full
        assert!(!m.can_admit(16, 0));
        assert!(m.can_ever_admit(16, 0)); // would fit an empty manager
        assert!(!m.can_ever_admit(1000, 100)); // over max_seq: never
        assert!(!m.can_ever_admit(64, 16)); // over the whole budget: never
    }

    #[test]
    fn double_admit_and_unknown_release_rejected() {
        let mut m = mgr(10);
        m.admit(1, &prompt(1, 16), 0).unwrap();
        assert!(m.admit(1, &prompt(1, 16), 0).is_err());
        assert!(m.release(99).is_err());
        m.release(1).unwrap();
        assert!(m.release(1).is_err());
        m.check_invariants().unwrap();
    }

    #[test]
    fn block_rounding() {
        let mut m = mgr(10);
        m.admit(1, &prompt(1, 1), 0).unwrap(); // 1 token still takes a whole block
        assert_eq!(m.free_blocks(), 9);
        m.admit(2, &prompt(2, 16), 1).unwrap(); // 17 tokens = 2 blocks
        assert_eq!(m.free_blocks(), 7);
        m.check_invariants().unwrap();
    }

    #[test]
    fn import_prefix_parks_evictable_blocks_the_next_admit_revives() {
        let mut m = mgr(10);
        let p = prompt(3, 64); // 4 full blocks
        assert_eq!(m.import_prefix(&p), 4);
        assert_eq!(m.evictable_blocks(), 4);
        assert_eq!(m.free_blocks(), 10, "evictable blocks still count as spare");
        m.check_invariants().unwrap();
        // The continuation's admission sees a full prefix hit.
        let g = m.admit(1, &p, 16).unwrap(); // 80 tokens = 5 blocks
        assert_eq!((g.shared_blocks, g.cached_tokens, g.new_blocks), (4, 64, 1));
        assert_eq!(m.evictable_blocks(), 0);
        // Re-importing a resident prefix is a no-op; a longer prefix
        // imports only its new tail blocks.
        assert_eq!(m.import_prefix(&p), 0);
        let mut longer = p.clone();
        longer.extend(prompt(4, 32));
        assert_eq!(m.import_prefix(&longer), 2);
        m.check_invariants().unwrap();
    }

    #[test]
    fn import_prefix_never_wedges_capacity() {
        let mut m = mgr(4);
        // 6 full blocks into a 4-block manager: the import stops when
        // the free pool runs dry instead of evicting its own entries.
        let imported = m.import_prefix(&prompt(5, 96));
        assert_eq!(imported, 4);
        m.check_invariants().unwrap();
        // The whole budget is still admissible: imports only park
        // evictable blocks, which allocation recycles freely.
        m.admit(1, &prompt(6, 48), 16).unwrap(); // 64 tokens = 4 blocks
        m.check_invariants().unwrap();
        // Sharing off: imports are a no-op.
        let mut off = BlockManager::new(BlockManagerConfig {
            block_size: 16,
            num_blocks: 4,
            max_seq: 1024,
            enable_prefix_sharing: false,
        });
        assert_eq!(off.import_prefix(&prompt(5, 96)), 0);
        assert_eq!(off.evictable_blocks(), 0);
    }

    #[test]
    fn identical_prompts_share_full_blocks() {
        let mut m = mgr(32);
        let p = prompt(7, 64); // 4 full blocks
        let g1 = m.admit(1, &p, 16).unwrap(); // 80 tokens = 5 blocks
        assert_eq!((g1.shared_blocks, g1.new_blocks, g1.cached_tokens), (0, 5, 0));
        let g2 = m.admit(2, &p, 16).unwrap();
        assert_eq!(g2.shared_blocks, 4);
        assert_eq!(g2.new_blocks, 1); // only the generation block
        assert_eq!(g2.cached_tokens, 64);
        assert!(!g2.cow_pending); // prompt ends on a block boundary
        assert_eq!(m.used_blocks(), 6); // 5 + 1, not 10
        assert_eq!(m.prefix_stats().hits, 4);
        assert_eq!(m.prefix_stats().blocks_saved(), 4);
        m.check_invariants().unwrap();
        // Release order doesn't matter: refcounts gate the free path.
        m.release(1).unwrap();
        m.check_invariants().unwrap();
        assert_eq!(m.num_seqs(), 1);
        m.release(2).unwrap();
        m.check_invariants().unwrap();
        assert_eq!(m.free_blocks(), 32);
    }

    #[test]
    fn diverging_prompts_share_only_the_common_prefix() {
        let mut m = mgr(32);
        let mut a = prompt(3, 48); // 3 full blocks
        m.admit(1, &a, 0).unwrap();
        a[40] += 1; // diverge inside block 2
        let g = m.admit(2, &a, 0).unwrap();
        assert_eq!(g.shared_blocks, 2);
        assert_eq!(g.new_blocks, 1);
        m.check_invariants().unwrap();
    }

    #[test]
    fn released_prefixes_stay_matchable_until_recycled() {
        let mut m = mgr(8);
        let p = prompt(9, 64); // 4 blocks
        m.admit(1, &p, 0).unwrap();
        m.release(1).unwrap();
        assert_eq!(m.free_blocks(), 8, "evictable blocks still count as free");
        assert_eq!(m.evictable_blocks(), 4);
        // The freed prefix revives for a matching prompt.
        let g = m.admit(2, &p, 16).unwrap();
        assert_eq!(g.shared_blocks, 4);
        assert_eq!(g.cached_tokens, 64);
        assert_eq!(m.prefix_stats().revived, 4);
        m.check_invariants().unwrap();
    }

    #[test]
    fn lru_eviction_recycles_freed_hashes_leaf_first() {
        let mut m = mgr(4);
        m.admit(1, &prompt(4, 64), 0).unwrap(); // all 4 blocks, hashed
        m.release(1).unwrap();
        assert_eq!(m.evictable_blocks(), 4);
        // A disjoint admission must recycle evictable blocks.
        let g = m.admit(2, &prompt(5, 32), 0).unwrap();
        assert_eq!(g.shared_blocks, 0);
        assert_eq!(m.prefix_stats().evictions, 2);
        // The recycled blocks were the chain's deepest (leaf-first), so
        // the surviving prefix root still matches a shorter prompt.
        let g = m.admit(3, &prompt(4, 32), 0).unwrap();
        assert_eq!(g.shared_blocks, 2, "prefix roots outlive leaves");
        m.check_invariants().unwrap();
    }

    #[test]
    fn cow_tail_share_forks_without_mutating_the_donor() {
        let mut m = mgr(16);
        let donor = prompt(6, 32); // 2 full blocks
        m.admit(1, &donor, 0).unwrap();
        // 20-token prompt: block 0 matches in full, the 4-token tail
        // matches the head of the donor's block 1.
        let short = donor[..20].to_vec();
        let g = m.admit(2, &short, 8).unwrap();
        assert_eq!(g.shared_blocks, 1);
        assert!(g.cow_pending);
        assert_eq!(g.cached_tokens, 20, "full block + matched tail");
        assert_eq!(g.new_blocks, 1, "the COW spare");
        m.check_invariants().unwrap();
        // First generated token: fork.
        assert!(m.cow_fork(2).unwrap());
        assert!(!m.cow_fork(2).unwrap(), "fork is one-shot");
        assert_eq!(m.prefix_stats().cow_forks, 1);
        m.check_invariants().unwrap();
        // Donor's block content is untouched and still fully matchable.
        m.release(2).unwrap();
        let again = m.admit(3, &donor, 0).unwrap();
        assert_eq!(again.shared_blocks, 2, "donor chain intact after fork");
        m.check_invariants().unwrap();
    }

    #[test]
    fn evictable_tail_donor_is_charged_against_spare_capacity() {
        // Regression: an evictable COW tail donor leaves the spare pool
        // when attached, exactly like an evictable full-block match. If
        // the probe failed to charge it, `can_admit_prompt` would
        // approve an admission whose spare allocation then finds both
        // pools empty — a panic in the admission controller and a
        // leaked refcount.
        let mut m = BlockManager::new(BlockManagerConfig {
            block_size: 16,
            num_blocks: 1,
            max_seq: 1024,
            ..Default::default()
        });
        let donor = prompt(1, 16); // exactly one full, hashed block
        m.admit(1, &donor, 0).unwrap();
        m.release(1).unwrap(); // the only block parks evictable
        assert_eq!(m.free_blocks(), 1);
        // 8-token tail of the donor + generation: tail_match fires, but
        // the donor itself is the only "free" block — attaching it
        // leaves nothing for the COW spare.
        let short = donor[..8].to_vec();
        let probe = m.probe(&short);
        assert!(probe.tail_match);
        assert_eq!(probe.matched_evictable, 1, "the evictable donor is charged");
        assert!(!m.can_admit_prompt(&short, 8));
        assert!(m.admit(2, &short, 8).is_err(), "graceful refusal, not a mid-admit panic");
        m.check_invariants().unwrap();
        assert_eq!(m.free_blocks(), 1, "the refused admission left no dangling refcount");
        // With one more block of headroom the same share admits fine.
        let mut m2 = BlockManager::new(BlockManagerConfig {
            block_size: 16,
            num_blocks: 2,
            max_seq: 1024,
            ..Default::default()
        });
        m2.admit(1, &donor, 0).unwrap();
        m2.release(1).unwrap();
        let g = m2.admit(2, &short, 8).unwrap();
        assert!(g.cow_pending);
        assert_eq!(g.new_blocks, 1, "the spare");
        m2.check_invariants().unwrap();
    }

    #[test]
    fn sharing_disabled_restores_the_prefix_blind_allocator() {
        let mut m = BlockManager::new(BlockManagerConfig {
            enable_prefix_sharing: false,
            num_blocks: 32,
            ..Default::default()
        });
        let p = prompt(8, 64);
        let g1 = m.admit(1, &p, 16).unwrap();
        let g2 = m.admit(2, &p, 16).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(g2.shared_blocks, 0);
        assert_eq!(g2.cached_tokens, 0);
        assert_eq!(m.used_blocks(), 10, "no sharing: 5 + 5");
        assert_eq!(m.prefix_stats(), PrefixCacheStats::default());
        m.release(1).unwrap();
        assert_eq!(m.evictable_blocks(), 0, "nothing is retained");
        m.check_invariants().unwrap();
    }

    #[test]
    fn probe_is_read_only_and_matches_admit() {
        let mut m = mgr(32);
        let p = prompt(2, 80); // 5 full blocks
        m.admit(1, &p, 0).unwrap();
        let before = format!("{m:?}");
        let probe = m.probe(&p);
        assert_eq!(format!("{m:?}"), before, "probe must not mutate");
        assert_eq!(probe.matched_blocks, 5);
        assert_eq!(probe.cached_tokens, 80);
        let g = m.admit(2, &p, 0).unwrap();
        assert_eq!(g.shared_blocks, probe.matched_blocks);
        assert_eq!(g.cached_tokens, probe.cached_tokens);
    }

    #[test]
    fn sharing_aware_admission_admits_what_blind_check_refuses() {
        let mut m = mgr(6);
        let p = prompt(1, 64); // 4 blocks
        m.admit(1, &p, 16).unwrap(); // 5 blocks: 1 free left
        assert!(!m.can_admit(64, 16), "prefix-blind: 5 blocks never fit 1");
        assert!(m.can_admit_prompt(&p, 16), "sharing: only the gen block is new");
        let g = m.admit(2, &p, 16).unwrap();
        assert_eq!(g.new_blocks, 1);
        assert_eq!(m.free_blocks(), 0);
        m.check_invariants().unwrap();
    }
}
