//! Paged KV-cache block manager.
//!
//! vLLM-style logical paging: cache capacity is tracked in fixed-size token
//! blocks; a request is admitted only if its worst-case block demand fits.
//! In this reproduction the *physical* cache is the dense per-bucket tensor
//! the AOT artifacts are compiled with (static shapes — the CUDA-Graph
//! analog), so the block manager governs admission, capacity accounting,
//! and slot assignment rather than physical page indirection; the
//! invariants (no over-allocation, no leaked blocks, no double-free) are
//! exactly vLLM's and are property-tested in rust/tests/.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::request::RequestId;

/// Block-manager configuration.
#[derive(Debug, Clone)]
pub struct BlockManagerConfig {
    /// Tokens per block (vLLM default 16).
    pub block_size: usize,
    /// Total block budget across all sequences.
    pub num_blocks: usize,
    /// Hard per-sequence token cap (the artifacts' max_seq).
    pub max_seq: usize,
}

impl Default for BlockManagerConfig {
    fn default() -> Self {
        // 4096 blocks x 16 tokens = 64k tokens of KV budget.
        BlockManagerConfig { block_size: 16, num_blocks: 4096, max_seq: 1024 }
    }
}

/// Per-sequence allocation state.
#[derive(Debug, Clone)]
struct SeqAlloc {
    blocks: usize,
    tokens: usize,
}

/// The block manager.
#[derive(Debug)]
pub struct BlockManager {
    cfg: BlockManagerConfig,
    free_blocks: usize,
    seqs: HashMap<RequestId, SeqAlloc>,
}

impl BlockManager {
    pub fn new(cfg: BlockManagerConfig) -> BlockManager {
        assert!(cfg.block_size > 0 && cfg.num_blocks > 0);
        BlockManager { free_blocks: cfg.num_blocks, cfg, seqs: HashMap::new() }
    }

    pub fn config(&self) -> &BlockManagerConfig {
        &self.cfg
    }

    pub fn free_blocks(&self) -> usize {
        self.free_blocks
    }

    pub fn used_blocks(&self) -> usize {
        self.cfg.num_blocks - self.free_blocks
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.block_size)
    }

    /// Can a request with `prompt_len` + `max_new` tokens be admitted now?
    /// (Worst-case reservation: vLLM's conservative admission avoids
    /// mid-generation eviction, which this engine doesn't implement.)
    pub fn can_admit(&self, prompt_len: usize, max_new: usize) -> bool {
        let total = prompt_len + max_new;
        total <= self.cfg.max_seq && self.blocks_for(total) <= self.free_blocks
    }

    /// Could this request be admitted on an *empty* manager? False means
    /// it can never run here (too long for `max_seq` or bigger than the
    /// whole block budget) — the admission controller rejects such
    /// requests at submission instead of letting them wedge a queue head
    /// forever.
    pub fn can_ever_admit(&self, prompt_len: usize, max_new: usize) -> bool {
        let total = prompt_len + max_new;
        total <= self.cfg.max_seq && self.blocks_for(total) <= self.cfg.num_blocks
    }

    /// Reserve blocks for a new sequence.
    pub fn admit(&mut self, id: RequestId, prompt_len: usize, max_new: usize) -> Result<()> {
        if self.seqs.contains_key(&id) {
            bail!("sequence {id} already admitted");
        }
        let total = prompt_len + max_new;
        if total > self.cfg.max_seq {
            bail!("sequence {id}: {total} tokens exceeds max_seq {}", self.cfg.max_seq);
        }
        let need = self.blocks_for(total);
        if need > self.free_blocks {
            bail!("sequence {id}: needs {need} blocks, only {} free", self.free_blocks);
        }
        self.free_blocks -= need;
        self.seqs.insert(id, SeqAlloc { blocks: need, tokens: total });
        Ok(())
    }

    /// Release a finished sequence's blocks.
    pub fn release(&mut self, id: RequestId) -> Result<()> {
        let Some(alloc) = self.seqs.remove(&id) else {
            bail!("release of unknown sequence {id}");
        };
        self.free_blocks += alloc.blocks;
        debug_assert!(self.free_blocks <= self.cfg.num_blocks);
        Ok(())
    }

    /// Tokens reserved for a sequence (diagnostics).
    pub fn reserved_tokens(&self, id: RequestId) -> Option<usize> {
        self.seqs.get(&id).map(|a| a.tokens)
    }

    /// Invariant check used by the property tests: free + Σ allocated ==
    /// total.
    pub fn check_invariants(&self) -> Result<()> {
        let allocated: usize = self.seqs.values().map(|a| a.blocks).sum();
        if allocated + self.free_blocks != self.cfg.num_blocks {
            bail!(
                "block accounting broken: {} allocated + {} free != {}",
                allocated,
                self.free_blocks,
                self.cfg.num_blocks
            );
        }
        for (id, a) in &self.seqs {
            if self.blocks_for(a.tokens) != a.blocks {
                bail!("sequence {id}: {} tokens but {} blocks", a.tokens, a.blocks);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(blocks: usize) -> BlockManager {
        BlockManager::new(BlockManagerConfig { block_size: 16, num_blocks: blocks, max_seq: 1024 })
    }

    #[test]
    fn admit_reserves_worst_case() {
        let mut m = mgr(10);
        // 100 prompt + 28 new = 128 tokens = 8 blocks.
        assert!(m.can_admit(100, 28));
        m.admit(1, 100, 28).unwrap();
        assert_eq!(m.free_blocks(), 2);
        assert_eq!(m.reserved_tokens(1), Some(128));
        m.check_invariants().unwrap();
    }

    #[test]
    fn admission_denied_when_full() {
        let mut m = mgr(4);
        m.admit(1, 48, 16).unwrap(); // 64 tokens = 4 blocks
        assert_eq!(m.free_blocks(), 0);
        assert!(!m.can_admit(1, 0));
        assert!(m.admit(2, 1, 0).is_err());
        m.release(1).unwrap();
        assert!(m.can_admit(1, 0));
        m.check_invariants().unwrap();
    }

    #[test]
    fn max_seq_enforced() {
        let mut m = mgr(1000);
        assert!(!m.can_admit(1000, 100));
        assert!(m.admit(1, 1000, 100).is_err());
        assert!(m.can_admit(1000, 24));
    }

    #[test]
    fn can_ever_admit_ignores_current_occupancy() {
        let mut m = mgr(4); // 64-token budget
        m.admit(1, 48, 16).unwrap(); // full
        assert!(!m.can_admit(16, 0));
        assert!(m.can_ever_admit(16, 0)); // would fit an empty manager
        assert!(!m.can_ever_admit(1000, 100)); // over max_seq: never
        assert!(!m.can_ever_admit(64, 16)); // over the whole budget: never
    }

    #[test]
    fn double_admit_and_unknown_release_rejected() {
        let mut m = mgr(10);
        m.admit(1, 16, 0).unwrap();
        assert!(m.admit(1, 16, 0).is_err());
        assert!(m.release(99).is_err());
        m.release(1).unwrap();
        assert!(m.release(1).is_err());
        m.check_invariants().unwrap();
    }

    #[test]
    fn block_rounding() {
        let mut m = mgr(10);
        m.admit(1, 1, 0).unwrap(); // 1 token still takes a whole block
        assert_eq!(m.free_blocks(), 9);
        m.admit(2, 16, 1).unwrap(); // 17 tokens = 2 blocks
        assert_eq!(m.free_blocks(), 7);
        m.check_invariants().unwrap();
    }
}
