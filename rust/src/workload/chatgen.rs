//! Synthetic chat traffic.
//!
//! §3.1 describes the target workload: "standard chat interactions …
//! Llama-3.1-70B-Instruct with short prompts (L_K <= 512, Batch = 1)".
//! This generator produces deterministic request streams with that shape:
//! prompt lengths from a truncated log-normal (chat prompts cluster short
//! with a long tail), output lengths geometric-ish, Poisson arrivals.

use crate::coordinator::Request;
use crate::util::prng::Rng;

/// A generated request plus its arrival offset.
#[derive(Debug, Clone)]
pub struct GeneratedRequest {
    pub request: Request,
    /// Arrival offset from stream start, µs.
    pub arrival_offset_us: u64,
}

/// Chat workload parameters.
#[derive(Debug, Clone)]
pub struct ChatWorkload {
    pub seed: u64,
    pub n_requests: usize,
    /// Median prompt length (tokens).
    pub prompt_median: usize,
    /// Hard cap on prompt length (the paper's L_K <= 512 regime).
    pub prompt_cap: usize,
    /// Mean output length (tokens).
    pub output_mean: usize,
    pub output_cap: usize,
    /// Mean inter-arrival gap, µs (0 = all at once / closed loop).
    pub mean_gap_us: u64,
    pub vocab: usize,
}

impl Default for ChatWorkload {
    fn default() -> Self {
        ChatWorkload {
            seed: 0xC4A7,
            n_requests: 16,
            prompt_median: 200,
            prompt_cap: 512,
            output_mean: 64,
            output_cap: 256,
            mean_gap_us: 0,
            vocab: 4096,
        }
    }
}

impl ChatWorkload {
    /// Generate the stream (deterministic in `seed`).
    pub fn generate(&self) -> Vec<GeneratedRequest> {
        assert!(self.n_requests > 0 && self.prompt_cap >= 1 && self.vocab >= 2);
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::with_capacity(self.n_requests);
        let mut clock = 0u64;
        for id in 0..self.n_requests {
            let prompt_len = self.sample_prompt_len(&mut rng);
            let out_len = self.sample_output_len(&mut rng);
            let prompt: Vec<i32> =
                (0..prompt_len).map(|_| rng.range(1, self.vocab - 1) as i32).collect();
            if self.mean_gap_us > 0 {
                // Exponential inter-arrival (Poisson process).
                let u = rng.f64().max(1e-12);
                clock += (-(u.ln()) * self.mean_gap_us as f64) as u64;
            }
            out.push(GeneratedRequest {
                request: Request::new(id as u64, prompt, out_len),
                arrival_offset_us: clock,
            });
        }
        out
    }

    fn sample_prompt_len(&self, rng: &mut Rng) -> usize {
        // Log-normal around the median, truncated to [1, cap].
        let sigma = 0.6;
        let ln = (self.prompt_median as f64).ln() + sigma * rng.normal();
        (ln.exp() as usize).clamp(1, self.prompt_cap)
    }

    fn sample_output_len(&self, rng: &mut Rng) -> usize {
        // Geometric with the requested mean, truncated.
        let p = 1.0 / self.output_mean as f64;
        let u = rng.f64().max(1e-12);
        (((1.0 - u).ln() / (1.0 - p).ln()).ceil() as usize).clamp(1, self.output_cap)
    }

    /// The §3 fitness workload: a fixed panel of short-prompt, Batch = 1
    /// chat generations crossing the heuristic's decision boundaries.
    pub fn evolution_panel() -> Vec<(usize, usize)> {
        // (prompt_len, n_tokens) pairs; chosen to cover every nblk bucket
        // the search can influence (1..4) plus a just-beyond control.
        vec![(64, 64), (192, 64), (320, 64), (384, 128), (440, 72), (576, 64)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_bounds() {
        let w = ChatWorkload { n_requests: 64, ..Default::default() };
        let a = w.generate();
        let b = w.generate();
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.prompt, y.request.prompt);
            assert_eq!(x.arrival_offset_us, y.arrival_offset_us);
        }
        for g in &a {
            assert!((1..=512).contains(&g.request.prompt.len()));
            assert!((1..=256).contains(&g.request.max_new_tokens));
            assert!(g.request.prompt.iter().all(|&t| t >= 1 && (t as usize) < 4096));
        }
    }

    #[test]
    fn prompt_distribution_clusters_short() {
        let w = ChatWorkload { n_requests: 500, ..Default::default() };
        let reqs = w.generate();
        let med = {
            let mut lens: Vec<usize> = reqs.iter().map(|r| r.request.prompt.len()).collect();
            lens.sort_unstable();
            lens[lens.len() / 2]
        };
        assert!((100..=380).contains(&med), "median prompt {med}");
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let w = ChatWorkload { mean_gap_us: 1000, n_requests: 50, ..Default::default() };
        let reqs = w.generate();
        let mut last = 0;
        for g in &reqs {
            assert!(g.arrival_offset_us >= last);
            last = g.arrival_offset_us;
        }
        assert!(last > 0);
    }

    #[test]
    fn closed_loop_has_zero_offsets() {
        let w = ChatWorkload::default();
        assert!(w.generate().iter().all(|g| g.arrival_offset_us == 0));
    }

    #[test]
    fn panel_covers_boundary() {
        let panel = ChatWorkload::evolution_panel();
        // At least one generation crosses into the 385..512 bucket.
        assert!(panel.iter().any(|&(p, n)| p + n > 384 && p < 512));
        // And one control beyond it.
        assert!(panel.iter().any(|&(p, _)| p > 512));
    }
}
