//! Synthetic chat traffic.
//!
//! §3.1 describes the target workload: "standard chat interactions …
//! Llama-3.1-70B-Instruct with short prompts (L_K <= 512, Batch = 1)".
//! This generator produces deterministic request streams with that shape:
//! prompt lengths from a truncated log-normal (chat prompts cluster short
//! with a long tail), output lengths geometric-ish, Poisson arrivals.

use crate::coordinator::{Priority, Request};
use crate::util::prng::Rng;

/// A generated request plus its arrival offset and chat-session identity.
#[derive(Debug, Clone)]
pub struct GeneratedRequest {
    pub request: Request,
    /// Arrival offset from stream start, µs.
    pub arrival_offset_us: u64,
    /// Admission class the driver should submit the request under.
    /// [`ChatWorkload::generate`] emits everything as
    /// [`Priority::Standard`]; [`ChatWorkload::mixed_open_loop`] tags its
    /// two sub-streams `Interactive` and `Batch` so per-class TTFT/TPOT
    /// splits are observable end to end.
    pub priority: Priority,
    /// Chat session the request belongs to. Consecutive requests share a
    /// session when [`ChatWorkload::turns_per_session`] > 1 — the unit a
    /// session-affinity router must keep on one replica (its KV lives
    /// there).
    pub session: u64,
    /// Turn index within the session (0-based).
    pub turn: usize,
}

/// Chat workload parameters.
#[derive(Debug, Clone)]
pub struct ChatWorkload {
    pub seed: u64,
    pub n_requests: usize,
    /// Median prompt length (tokens).
    pub prompt_median: usize,
    /// Floor on prompt length (1 = unconstrained). Heavy-decode benches
    /// pin it to the boundary bucket's lower edge so the regime under
    /// test actually dominates the trace.
    pub prompt_min: usize,
    /// Hard cap on the *sampled* prompt length — the unique suffix when
    /// [`ChatWorkload::shared_prefix_len`] > 0 (the system prefix is
    /// additive: total prompt = `shared_prefix_len` + sampled). Keep
    /// `shared_prefix_len + prompt_cap + output_cap` within the serving
    /// engine's `max_seq` or the tail of the distribution is refused as
    /// unschedulable. (The paper's regime is L_K <= 512.)
    pub prompt_cap: usize,
    /// Mean output length (tokens).
    pub output_mean: usize,
    pub output_cap: usize,
    /// Mean inter-arrival gap, µs (0 = all at once / closed loop).
    pub mean_gap_us: u64,
    pub vocab: usize,
    /// Requests per chat session (multi-turn conversations). 1 = every
    /// request is its own session.
    pub turns_per_session: usize,
    /// Shared system-prompt length, tokens. When > 0 every prompt is
    /// `system prefix ++ unique suffix`: requests in the same fan-out
    /// group draw byte-identical prefixes, which is exactly what the
    /// prefix-sharing KV cache deduplicates. **Additive** on top of the
    /// sampled suffix length (see [`ChatWorkload::prompt_cap`]).
    /// 0 = scenario off.
    pub shared_prefix_len: usize,
    /// Requests per distinct system prompt (fan-out). Group `g` holds
    /// requests `g*fanout .. (g+1)*fanout`; `fanout = 1` gives every
    /// request its own prefix — same lengths and arrivals as the shared
    /// scenario, zero sharable content (the disjoint A/B control).
    pub prefix_fanout: usize,
}

impl Default for ChatWorkload {
    fn default() -> Self {
        ChatWorkload {
            seed: 0xC4A7,
            n_requests: 16,
            prompt_median: 200,
            prompt_min: 1,
            prompt_cap: 512,
            output_mean: 64,
            output_cap: 256,
            mean_gap_us: 0,
            vocab: 4096,
            turns_per_session: 1,
            shared_prefix_len: 0,
            prefix_fanout: 1,
        }
    }
}

impl ChatWorkload {
    /// This workload with a different seed (same shape parameters) — the
    /// explicit reseeding knob for A/B pairs that must replay one stream.
    pub fn with_seed(mut self, seed: u64) -> ChatWorkload {
        self.seed = seed;
        self
    }

    /// This workload reseeded for one replica's independent stream —
    /// distinct, deterministic, run-to-run reproducible seeds per replica
    /// index (SplitMix-style decorrelation so adjacent indices don't share
    /// low-bit structure). For replica-local drivers that bypass the fleet
    /// router and saturate each replica with its own traffic
    /// (`tests/cluster_fleet.rs` exercises the reproducibility contract).
    pub fn stream_for_replica(&self, replica: usize) -> ChatWorkload {
        let mixed = self.seed ^ (replica as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        self.clone().with_seed(Rng::new(mixed).next_u64())
    }

    /// The paper's heavy-decode regime: prompts pinned to [385, 448]
    /// (median 420) so every decode trajectory traverses the
    /// `L_K = 385..512` boundary bucket where the sequence-aware override
    /// fires, with fixed-length outputs. The one definition shared by the
    /// cluster bench and the fleet test suite — the regime window lives
    /// here, not in N copies.
    pub fn boundary_bucket(seed: u64, n_requests: usize, output: usize) -> ChatWorkload {
        ChatWorkload {
            seed,
            n_requests,
            prompt_median: 420,
            prompt_min: 385,
            prompt_cap: 448,
            output_mean: output,
            output_cap: output,
            ..Default::default()
        }
    }

    /// The prefix-sharing production scenario: `n_requests` chats where
    /// every group of `fanout` consecutive requests opens with the same
    /// `prefix_len`-token system prompt, followed by a unique chat
    /// suffix. `fanout = 1` is the matched disjoint control (identical
    /// suffixes, lengths, and arrivals; nothing sharable) — the A/B pair
    /// the `prefix_cache` bench sweeps.
    pub fn shared_system_prompt(
        seed: u64,
        n_requests: usize,
        prefix_len: usize,
        fanout: usize,
        output: usize,
    ) -> ChatWorkload {
        ChatWorkload {
            seed,
            n_requests,
            shared_prefix_len: prefix_len,
            prefix_fanout: fanout.max(1),
            output_mean: output,
            output_cap: output,
            ..Default::default()
        }
    }

    /// The continuous-batching mixed open-loop trace: two Poisson
    /// streams merged by arrival time. Three quarters of the requests
    /// are short interactive chats ([`Priority::Interactive`], prompts
    /// clustering under ~256 tokens, short outputs); the remaining
    /// quarter are long-prompt batch jobs ([`Priority::Batch`], prompts
    /// pinned to [384, 768], small outputs) — the monolithic prefill of
    /// one batch prompt is exactly the head-of-line blocker chunked
    /// prefill exists to break up. Each sub-stream's inter-arrival gap
    /// is scaled so the *merged* stream has mean gap `mean_gap_us`.
    /// Ids are reassigned contiguously after the merge (submission
    /// order), deterministic in `seed`.
    pub fn mixed_open_loop(
        seed: u64,
        n_requests: usize,
        mean_gap_us: u64,
    ) -> Vec<GeneratedRequest> {
        assert!(n_requests > 0, "mixed_open_loop needs at least one request");
        let n_batch = (n_requests / 4).max(1).min(n_requests);
        let n_interactive = n_requests - n_batch;
        // Per-stream gaps: merged rate = sum of stream rates, so each
        // stream slows down by its share of the traffic.
        let scale = |n: usize| {
            if n == 0 || mean_gap_us == 0 {
                mean_gap_us
            } else {
                mean_gap_us * n_requests as u64 / n as u64
            }
        };
        let interactive = ChatWorkload {
            seed,
            n_requests: n_interactive.max(1),
            prompt_median: 96,
            prompt_cap: 256,
            output_mean: 32,
            output_cap: 64,
            mean_gap_us: scale(n_interactive),
            ..Default::default()
        };
        let batch = ChatWorkload {
            seed: Rng::new(seed ^ 0x6d69_7865_646c_6f61).next_u64(),
            n_requests: n_batch,
            prompt_median: 480,
            prompt_min: 384,
            prompt_cap: 768,
            output_mean: 16,
            output_cap: 32,
            mean_gap_us: scale(n_batch),
            ..Default::default()
        };
        let mut fast = if n_interactive > 0 { interactive.generate() } else { Vec::new() };
        let mut slow = batch.generate();
        for g in &mut fast {
            g.priority = Priority::Interactive;
        }
        for g in &mut slow {
            g.priority = Priority::Batch;
        }
        // Merge by arrival; interactive wins ties so the latency-critical
        // class is never queued behind a simultaneous batch arrival.
        let mut out = Vec::with_capacity(n_interactive + n_batch);
        let (mut i, mut j) = (0, 0);
        while i < fast.len() || j < slow.len() {
            let take_fast = match (fast.get(i), slow.get(j)) {
                (Some(f), Some(s)) => f.arrival_offset_us <= s.arrival_offset_us,
                (Some(_), None) => true,
                _ => false,
            };
            let mut g = if take_fast {
                i += 1;
                fast[i - 1].clone()
            } else {
                j += 1;
                slow[j - 1].clone()
            };
            g.request.id = out.len() as u64;
            g.session = g.request.id;
            g.turn = 0;
            out.push(g);
        }
        out
    }

    /// Flash-crowd overload trace: the [`ChatWorkload::mixed_open_loop`]
    /// stream with the middle third of its requests arriving
    /// `burst_factor`× faster (inter-arrival gaps divided, offsets
    /// rebuilt so the stream stays monotone). The prompts, outputs,
    /// priorities, and ids are byte-identical to the un-warped stream —
    /// only the clock moves — so overload A/B pairs (preemption on vs
    /// off, burst vs steady) compare the same work under different
    /// pressure. `burst_factor = 1` is the identity.
    pub fn flash_crowd(
        seed: u64,
        n_requests: usize,
        mean_gap_us: u64,
        burst_factor: u64,
    ) -> Vec<GeneratedRequest> {
        assert!(burst_factor >= 1, "burst_factor must be >= 1");
        let mut reqs = ChatWorkload::mixed_open_loop(seed, n_requests, mean_gap_us);
        let (start, end) = (n_requests / 3, 2 * n_requests / 3);
        let mut clock = 0u64;
        let mut prev_raw = 0u64;
        for (i, g) in reqs.iter_mut().enumerate() {
            let gap = g.arrival_offset_us - prev_raw;
            prev_raw = g.arrival_offset_us;
            clock += if (start..end).contains(&i) { gap / burst_factor } else { gap };
            g.arrival_offset_us = clock;
        }
        reqs
    }

    /// Diurnal overload trace: the mixed open-loop stream with its
    /// arrival rate modulated sinusoidally over `period_us` —
    /// `rate(t) = 1 + 0.8·sin(2πt/period)`, so the peak runs 1.8× the
    /// mean rate and the trough 0.2×. Same warp contract as
    /// [`ChatWorkload::flash_crowd`]: only arrival offsets move.
    pub fn diurnal(
        seed: u64,
        n_requests: usize,
        mean_gap_us: u64,
        period_us: u64,
    ) -> Vec<GeneratedRequest> {
        assert!(period_us > 0, "period_us must be positive");
        let mut reqs = ChatWorkload::mixed_open_loop(seed, n_requests, mean_gap_us);
        let mut clock = 0u64;
        let mut prev_raw = 0u64;
        for g in reqs.iter_mut() {
            let gap = g.arrival_offset_us - prev_raw;
            prev_raw = g.arrival_offset_us;
            let phase = 2.0 * std::f64::consts::PI * clock as f64 / period_us as f64;
            let rate = 1.0 + 0.8 * phase.sin();
            clock += (gap as f64 / rate) as u64;
            g.arrival_offset_us = clock;
        }
        reqs
    }

    /// Generate the stream (deterministic in `seed`).
    pub fn generate(&self) -> Vec<GeneratedRequest> {
        assert!(self.n_requests > 0 && self.prompt_cap >= 1 && self.vocab >= 2);
        assert!(self.turns_per_session >= 1, "turns_per_session must be >= 1");
        assert!(self.prompt_min <= self.prompt_cap, "prompt_min exceeds prompt_cap");
        assert!(self.prefix_fanout >= 1, "prefix_fanout must be >= 1");
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::with_capacity(self.n_requests);
        let mut clock = 0u64;
        for id in 0..self.n_requests {
            let prompt_len = self.sample_prompt_len(&mut rng);
            let out_len = self.sample_output_len(&mut rng);
            // The system prefix draws from a per-group stream, NOT the
            // main one: changing `prefix_fanout` regroups the prefixes
            // without shifting a single suffix, length, or arrival draw,
            // so shared-vs-disjoint comparisons are exact A/B pairs.
            let mut prompt: Vec<i32> = Vec::with_capacity(self.shared_prefix_len + prompt_len);
            if self.shared_prefix_len > 0 {
                let group = (id / self.prefix_fanout) as u64;
                let mut prefix_rng =
                    Rng::new(self.seed ^ (group + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
                prompt.extend(
                    (0..self.shared_prefix_len)
                        .map(|_| prefix_rng.range(1, self.vocab - 1) as i32),
                );
            }
            prompt.extend((0..prompt_len).map(|_| rng.range(1, self.vocab - 1) as i32));
            if self.mean_gap_us > 0 {
                // Exponential inter-arrival (Poisson process).
                let u = rng.f64().max(1e-12);
                clock += (-(u.ln()) * self.mean_gap_us as f64) as u64;
            }
            out.push(GeneratedRequest {
                request: Request::new(id as u64, prompt, out_len),
                arrival_offset_us: clock,
                priority: Priority::Standard,
                session: (id / self.turns_per_session) as u64,
                turn: id % self.turns_per_session,
            });
        }
        out
    }

    fn sample_prompt_len(&self, rng: &mut Rng) -> usize {
        // Log-normal around the median, truncated to [prompt_min, cap].
        let sigma = 0.6;
        let ln = (self.prompt_median as f64).ln() + sigma * rng.normal();
        (ln.exp() as usize).clamp(self.prompt_min.max(1), self.prompt_cap)
    }

    fn sample_output_len(&self, rng: &mut Rng) -> usize {
        // Geometric with the requested mean, truncated.
        let p = 1.0 / self.output_mean as f64;
        let u = rng.f64().max(1e-12);
        (((1.0 - u).ln() / (1.0 - p).ln()).ceil() as usize).clamp(1, self.output_cap)
    }

    /// The §3 fitness workload: a fixed panel of short-prompt, Batch = 1
    /// chat generations crossing the heuristic's decision boundaries.
    pub fn evolution_panel() -> Vec<(usize, usize)> {
        // (prompt_len, n_tokens) pairs; chosen to cover every nblk bucket
        // the search can influence (1..4) plus a just-beyond control.
        vec![(64, 64), (192, 64), (320, 64), (384, 128), (440, 72), (576, 64)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_bounds() {
        let w = ChatWorkload { n_requests: 64, ..Default::default() };
        let a = w.generate();
        let b = w.generate();
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.prompt, y.request.prompt);
            assert_eq!(x.arrival_offset_us, y.arrival_offset_us);
        }
        for g in &a {
            assert!((1..=512).contains(&g.request.prompt.len()));
            assert!((1..=256).contains(&g.request.max_new_tokens));
            assert!(g.request.prompt.iter().all(|&t| t >= 1 && (t as usize) < 4096));
        }
    }

    #[test]
    fn prompt_distribution_clusters_short() {
        let w = ChatWorkload { n_requests: 500, ..Default::default() };
        let reqs = w.generate();
        let med = {
            let mut lens: Vec<usize> = reqs.iter().map(|r| r.request.prompt.len()).collect();
            lens.sort_unstable();
            lens[lens.len() / 2]
        };
        assert!((100..=380).contains(&med), "median prompt {med}");
    }

    #[test]
    fn prompt_floor_pins_the_regime() {
        let w = ChatWorkload { n_requests: 100, prompt_min: 385, ..Default::default() };
        // Median 200 < floor 385: everything clamps into [385, 512].
        assert!(w
            .generate()
            .iter()
            .all(|g| (385..=512).contains(&g.request.prompt.len())));
    }

    #[test]
    fn boundary_bucket_stays_inside_the_window() {
        let reqs = ChatWorkload::boundary_bucket(3, 50, 64).generate();
        assert_eq!(reqs.len(), 50);
        for g in &reqs {
            let p = g.request.prompt.len();
            assert!((385..=448).contains(&p), "prompt {p} outside [385, 448]");
            assert_eq!(g.request.max_new_tokens, 64);
            // Every decode step's L_K stays <= 512: nblk = 4 throughout.
            assert!(p + 64 <= 512);
        }
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let w = ChatWorkload { mean_gap_us: 1000, n_requests: 50, ..Default::default() };
        let reqs = w.generate();
        let mut last = 0;
        for g in &reqs {
            assert!(g.arrival_offset_us >= last);
            last = g.arrival_offset_us;
        }
        assert!(last > 0);
    }

    #[test]
    fn closed_loop_has_zero_offsets() {
        let w = ChatWorkload::default();
        assert!(w.generate().iter().all(|g| g.arrival_offset_us == 0));
    }

    #[test]
    fn sessions_group_consecutive_turns() {
        let w = ChatWorkload { n_requests: 10, turns_per_session: 4, ..Default::default() };
        let reqs = w.generate();
        let sessions: Vec<u64> = reqs.iter().map(|g| g.session).collect();
        assert_eq!(sessions, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2]);
        let turns: Vec<usize> = reqs.iter().map(|g| g.turn).collect();
        assert_eq!(turns, vec![0, 1, 2, 3, 0, 1, 2, 3, 0, 1]);
        // Default: every request is its own session.
        let solo = ChatWorkload { n_requests: 3, ..Default::default() }.generate();
        assert!(solo.iter().all(|g| g.session == g.request.id && g.turn == 0));
    }

    #[test]
    fn replica_streams_are_distinct_and_reproducible() {
        let w = ChatWorkload { n_requests: 8, ..Default::default() };
        let a0 = w.stream_for_replica(0).generate();
        let a0_again = w.stream_for_replica(0).generate();
        let a1 = w.stream_for_replica(1).generate();
        for (x, y) in a0.iter().zip(&a0_again) {
            assert_eq!(x.request.prompt, y.request.prompt, "same replica ⇒ same stream");
        }
        assert_ne!(
            a0.iter().map(|g| g.request.prompt.len()).collect::<Vec<_>>(),
            a1.iter().map(|g| g.request.prompt.len()).collect::<Vec<_>>(),
            "different replicas draw different streams"
        );
        // with_seed is the underlying explicit knob.
        assert_eq!(
            w.clone().with_seed(99).generate().len(),
            ChatWorkload { seed: 99, n_requests: 8, ..Default::default() }.generate().len()
        );
    }

    #[test]
    fn shared_system_prompt_groups_share_exactly_the_prefix() {
        let w = ChatWorkload::shared_system_prompt(11, 12, 64, 4, 16);
        let reqs = w.generate();
        assert_eq!(reqs.len(), 12);
        for (i, g) in reqs.iter().enumerate() {
            assert!(g.request.prompt.len() > 64, "prefix plus a nonempty suffix");
            // Same group ⇒ byte-identical prefix; adjacent groups differ.
            let group_head = &reqs[(i / 4) * 4];
            assert_eq!(g.request.prompt[..64], group_head.request.prompt[..64]);
        }
        assert_ne!(
            reqs[0].request.prompt[..64],
            reqs[4].request.prompt[..64],
            "distinct groups draw distinct system prompts"
        );
        // Suffixes stay unique even inside a group (chat turns differ).
        assert_ne!(reqs[0].request.prompt[64..], reqs[1].request.prompt[64..]);
    }

    #[test]
    fn prefix_fanout_is_an_exact_ab_knob() {
        // Changing ONLY the fan-out must not move a single suffix,
        // length, or arrival: shared vs disjoint is an exact A/B pair.
        let shared = ChatWorkload {
            shared_prefix_len: 128,
            prefix_fanout: 8,
            n_requests: 16,
            mean_gap_us: 500,
            ..Default::default()
        };
        let disjoint = ChatWorkload { prefix_fanout: 1, ..shared.clone() };
        let a = shared.generate();
        let b = disjoint.generate();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.prompt.len(), y.request.prompt.len());
            assert_eq!(x.request.prompt[128..], y.request.prompt[128..], "suffixes identical");
            assert_eq!(x.request.max_new_tokens, y.request.max_new_tokens);
            assert_eq!(x.arrival_offset_us, y.arrival_offset_us);
        }
        // Disjoint control: every request has its own prefix.
        assert_ne!(b[0].request.prompt[..128], b[1].request.prompt[..128]);
        // Off switch: no prefix at all.
        let off = ChatWorkload { shared_prefix_len: 0, ..shared };
        assert_eq!(off.generate()[0].request.prompt.len(), a[0].request.prompt.len() - 128);
    }

    #[test]
    fn generate_defaults_to_standard_priority() {
        let reqs = ChatWorkload { n_requests: 4, ..Default::default() }.generate();
        assert!(reqs.iter().all(|g| g.priority == Priority::Standard));
    }

    #[test]
    fn mixed_open_loop_merges_two_classes() {
        let reqs = ChatWorkload::mixed_open_loop(7, 32, 1_000);
        let again = ChatWorkload::mixed_open_loop(7, 32, 1_000);
        assert_eq!(reqs.len(), 32);
        // Deterministic, ids contiguous in submission order, arrivals
        // monotone (the merge invariant the open-loop driver relies on).
        let mut last = 0u64;
        for (i, (g, h)) in reqs.iter().zip(&again).enumerate() {
            assert_eq!(g.request.prompt, h.request.prompt);
            assert_eq!(g.priority, h.priority);
            assert_eq!(g.request.id, i as u64);
            assert!(g.arrival_offset_us >= last);
            last = g.arrival_offset_us;
        }
        // 3:1 class mix with the documented shapes.
        let batch: Vec<_> = reqs.iter().filter(|g| g.priority == Priority::Batch).collect();
        let inter: Vec<_> =
            reqs.iter().filter(|g| g.priority == Priority::Interactive).collect();
        assert_eq!(batch.len(), 8);
        assert_eq!(inter.len(), 24);
        assert!(batch.iter().all(|g| (384..=768).contains(&g.request.prompt.len())));
        assert!(inter.iter().all(|g| g.request.prompt.len() <= 256));
    }

    #[test]
    fn mixed_open_loop_closed_loop_interleaves_interactive_first() {
        let reqs = ChatWorkload::mixed_open_loop(3, 8, 0);
        assert!(reqs.iter().all(|g| g.arrival_offset_us == 0));
        // Tie-break: every interactive request precedes every batch one.
        let first_batch =
            reqs.iter().position(|g| g.priority == Priority::Batch).unwrap();
        assert!(reqs[..first_batch].iter().all(|g| g.priority == Priority::Interactive));
        assert!(reqs[first_batch..].iter().all(|g| g.priority == Priority::Batch));
    }

    #[test]
    fn flash_crowd_compresses_only_the_burst_window() {
        let base = ChatWorkload::mixed_open_loop(9, 60, 2_000);
        let crowd = ChatWorkload::flash_crowd(9, 60, 2_000, 4);
        let again = ChatWorkload::flash_crowd(9, 60, 2_000, 4);
        // Same work, different clock: prompts/priorities/ids untouched.
        let mut last = 0u64;
        for ((b, c), c2) in base.iter().zip(&crowd).zip(&again) {
            assert_eq!(b.request.prompt, c.request.prompt);
            assert_eq!(b.priority, c.priority);
            assert_eq!(b.request.id, c.request.id);
            assert_eq!(c.arrival_offset_us, c2.arrival_offset_us, "deterministic");
            assert!(c.arrival_offset_us >= last, "monotone arrivals");
            last = c.arrival_offset_us;
        }
        // The middle third spans ~1/4 the time it took un-warped.
        let span = |r: &[GeneratedRequest]| {
            r[39].arrival_offset_us.saturating_sub(r[20].arrival_offset_us)
        };
        assert!(span(&crowd) * 3 < span(&base), "burst window must compress");
        // Identity factor leaves the stream untouched.
        let id = ChatWorkload::flash_crowd(9, 60, 2_000, 1);
        for (b, i) in base.iter().zip(&id) {
            assert_eq!(b.arrival_offset_us, i.arrival_offset_us);
        }
    }

    #[test]
    fn diurnal_peak_is_denser_than_trough() {
        let reqs = ChatWorkload::diurnal(5, 200, 1_000, 50_000);
        let again = ChatWorkload::diurnal(5, 200, 1_000, 50_000);
        let mut last = 0u64;
        for (g, h) in reqs.iter().zip(&again) {
            assert_eq!(g.arrival_offset_us, h.arrival_offset_us, "deterministic");
            assert!(g.arrival_offset_us >= last, "monotone arrivals");
            last = g.arrival_offset_us;
        }
        // Count arrivals in the first half-period (rate > 1, the peak)
        // vs the second (rate < 1, the trough): the peak must be denser.
        let peak = reqs.iter().filter(|g| g.arrival_offset_us < 25_000).count();
        let trough = reqs
            .iter()
            .filter(|g| (25_000..50_000).contains(&g.arrival_offset_us))
            .count();
        assert!(peak > trough, "peak {peak} <= trough {trough}");
    }

    #[test]
    fn panel_covers_boundary() {
        let panel = ChatWorkload::evolution_panel();
        // At least one generation crosses into the 385..512 bucket.
        assert!(panel.iter().any(|&(p, n)| p + n > 384 && p < 512));
        // And one control beyond it.
        assert!(panel.iter().any(|&(p, _)| p > 512));
    }
}
