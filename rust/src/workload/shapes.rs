//! The paper's evaluation shape grids.

use crate::heuristics::tiles::DecodeShape;

/// One Table-1 configuration (Batch = 1, D = 128, H_Q = 8·H_KV).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Table1Row {
    pub l_k: usize,
    pub h_kv: usize,
    /// Reported upstream latency, µs (for the paper-vs-measured column).
    pub paper_standard_us: f64,
    /// Reported patched latency, µs.
    pub paper_patched_us: f64,
}

impl Table1Row {
    /// The decode shape of this scenario row.
    pub fn shape(&self) -> DecodeShape {
        DecodeShape::decode(1, self.l_k, 8 * self.h_kv, self.h_kv, 128)
    }

    /// The paper-reported speedup for this row.
    pub fn paper_speedup(&self) -> f64 {
        self.paper_standard_us / self.paper_patched_us
    }
}

/// Table 1 of the paper, verbatim.
pub fn table1_grid() -> Vec<Table1Row> {
    let rows = [
        (128, 1, 9.56, 9.56),
        (128, 2, 9.45, 9.45),
        (128, 8, 9.46, 9.46),
        (256, 1, 11.57, 11.57),
        (256, 2, 11.58, 11.58),
        (256, 8, 11.60, 11.60),
        (384, 1, 13.60, 13.60),
        (384, 2, 13.57, 13.57),
        (384, 8, 13.55, 13.55),
        (512, 1, 13.72, 11.37),
        (512, 2, 13.52, 10.93),
        (512, 8, 13.56, 13.56),
        (2048, 1, 11.99, 11.99),
        (2048, 2, 12.66, 12.66),
        (2048, 8, 12.73, 12.73),
        (4096, 1, 13.88, 13.88),
        (4096, 2, 13.53, 13.53),
        (4096, 8, 15.05, 15.05),
    ];
    rows.into_iter()
        .map(|(l_k, h_kv, s, p)| Table1Row {
            l_k,
            h_kv,
            paper_standard_us: s,
            paper_patched_us: p,
        })
        .collect()
}

/// §5.3's 160-configuration regression grid:
/// Batch ∈ {1,2,4,8} × L_K ∈ {128,…,8192} × H_KV ∈ {1,2,4,8,32}.
pub fn regression_grid() -> Vec<DecodeShape> {
    let batches = [1usize, 2, 4, 8];
    let l_ks = [128usize, 256, 384, 512, 1024, 2048, 4096, 8192];
    let h_kvs = [1usize, 2, 4, 8, 32];
    let mut out = Vec::with_capacity(batches.len() * l_ks.len() * h_kvs.len());
    for &b in &batches {
        for &l_k in &l_ks {
            for &h_kv in &h_kvs {
                out.push(DecodeShape::decode(b, l_k, 8 * h_kv, h_kv, 128));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_eighteen_rows() {
        let g = table1_grid();
        assert_eq!(g.len(), 18);
        // The winning cells.
        let w1 = g.iter().find(|r| r.l_k == 512 && r.h_kv == 1).unwrap();
        assert!((w1.paper_speedup() - 1.2067).abs() < 1e-3);
        let w2 = g.iter().find(|r| r.l_k == 512 && r.h_kv == 2).unwrap();
        assert!((w2.paper_speedup() - 1.2369).abs() < 1e-3);
        // Everything else is 1.00x.
        let controls = g.iter().filter(|r| !(r.l_k == 512 && r.h_kv <= 2));
        for c in controls {
            assert!((c.paper_speedup() - 1.0).abs() < 1e-9, "{c:?}");
        }
    }

    #[test]
    fn regression_grid_is_160() {
        let g = regression_grid();
        assert_eq!(g.len(), 160); // 4 x 8 x 5, §5.3
        assert!(g.iter().all(|s| s.h_q == 8 * s.h_kv && s.d == 128 && s.l_q == 1));
    }
}
