//! Workload generators: the shapes and request streams the paper evaluates.
//!
//! * [`shapes`]  — the exact Table-1 / §5.3 shape grids,
//! * [`chatgen`] — synthetic chat traffic (§3.1's "standard chat
//!   interactions": short prompts, Batch = 1) for the serving benches and
//!   the evolutionary search's fitness workload.

pub mod chatgen;
pub mod shapes;

pub use chatgen::{ChatWorkload, GeneratedRequest};
pub use shapes::{regression_grid, table1_grid, Table1Row};
