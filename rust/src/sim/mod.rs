//! H100 SM-level latency simulator for the FA3 decode kernel.
//!
//! The paper's effect is *occupancy arithmetic* on a 132-SM Hopper part:
//! tiles = Batch x H_KV work units, split s ways, wave-quantized onto SMs,
//! paying a split-combine reduction when s > 1. None of that is ISA-level —
//! so a calibrated analytical SM/wave model reproduces the paper's
//! who-wins/by-how-much/where-crossovers-fall on hardware we don't have
//! (DESIGN.md §Substitutions). Kernel *numerics* run for real through the
//! Pallas-lowered HLO on the CPU PJRT backend (`runtime/`); this module is
//! the *latency* oracle for benches, the serving simulator mode, and the
//! evolutionary search's fitness function.
//!
//! Modules:
//! * [`gpu`]           — device descriptions (H100 SXM5 and variants),
//! * [`calibration`]   — cost-model constants fitted to the paper's anchors,
//! * [`kernel_model`]  — the launch-latency model itself,
//! * [`host_transfer`] — the KV swap-out/swap-in latency ledger and the
//!                       recompute estimate (preemption resume costs),
//! * [`trace`]         — multi-step decode traces and TPOT aggregation.

pub mod calibration;
pub mod gpu;
pub mod host_transfer;
pub mod kernel_model;
pub mod trace;

pub use calibration::Calibration;
pub use gpu::GpuSpec;
pub use host_transfer::{recompute_estimate_us, HostTransferModel, DECODE_STEP_ESTIMATE_US};
pub use kernel_model::{simulate_kernel, KernelTiming, Simulator};
pub use trace::{DecodeTrace, TraceSummary};
