//! Modeled host-transfer ledger for KV swap-out/swap-in, and the
//! matching recompute-cost estimate — the two sides of the engine's
//! swap-vs-recompute resume decision (`coordinator::ResumePolicy`).
//!
//! Nothing moves real bytes: like the rest of `sim/`, this is a latency
//! oracle on the engine's virtual clock. A preempted request whose KV is
//! *swapped* parks here for the modeled PCIe round trip and may not
//! re-admit before `ready_at`; one whose KV is *recomputed* pays nothing
//! up front but re-prefills its prompt and regenerates its tokens after
//! re-admission (chunked through the step composer). The decision rule
//! compares those two modeled costs per victim at preemption time.
//!
//! Constants are anchored the same way `kernel_model` is: a 16-token KV
//! block of a Llama-70B-class layer stack is a few hundred KiB, and at
//! ~25 GiB/s effective H2D/D2H that is ~10 µs of wire time per block on
//! top of a fixed submission latency; recompute reuses the
//! `Simulator::prefill_us` anchor (50 µs + 0.05 µs/token) plus the
//! per-token decode estimate for regeneration.

use super::kernel_model::Simulator;

/// Per-token decode-step estimate (µs) used when sizing recompute: one
/// generated token costs one decode step, and the paper's decode anchors
/// sit at ~10–14 µs/step including framework overhead.
pub const DECODE_STEP_ESTIMATE_US: f64 = 12.0;

/// The host-transfer latency model for swapped KV blocks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostTransferModel {
    /// Fixed cost per transfer direction (submission + sync), µs.
    pub base_us: f64,
    /// Wire cost per KV block per direction, µs.
    pub us_per_block: f64,
}

impl Default for HostTransferModel {
    fn default() -> Self {
        HostTransferModel { base_us: 20.0, us_per_block: 10.0 }
    }
}

impl HostTransferModel {
    /// Derive a transfer model from raw link parameters: a fixed per-hop
    /// submission latency plus a bandwidth-priced per-block wire cost for
    /// blocks of `block_bytes`. A non-positive or infinite bandwidth maps
    /// to a free wire (`us_per_block == 0.0`) — the `Interconnect::ZERO`
    /// link the differential tests use to prove disaggregation degenerates
    /// to the colocated fleet when transfers cost nothing.
    pub fn for_link(base_us: f64, gbps: f64, block_bytes: usize) -> HostTransferModel {
        let us_per_block = if gbps <= 0.0 || gbps.is_infinite() {
            0.0
        } else {
            // bytes / (GB/s) = ns, so divide by 1e3 more for µs.
            block_bytes as f64 / (gbps * 1e3)
        };
        HostTransferModel { base_us, us_per_block }
    }

    /// Device-to-host cost of parking `blocks` KV blocks, µs.
    pub fn swap_out_us(&self, blocks: usize) -> f64 {
        self.base_us + self.us_per_block * blocks as f64
    }

    /// Host-to-device cost of restoring `blocks` KV blocks, µs.
    pub fn swap_in_us(&self, blocks: usize) -> f64 {
        self.base_us + self.us_per_block * blocks as f64
    }

    /// Full park-and-restore round trip, µs: the earliest a swapped
    /// victim can be running again, relative to its preemption instant.
    pub fn round_trip_us(&self, blocks: usize) -> f64 {
        self.swap_out_us(blocks) + self.swap_in_us(blocks)
    }
}

/// Modeled cost of resuming by recompute: re-prefill the prompt (full
/// price — the conservative bound; the prefix cache can only make the
/// real run cheaper) plus one decode step per already-generated token
/// that must be regenerated.
pub fn recompute_estimate_us(sim: &Simulator, prompt_len: usize, generated: usize) -> f64 {
    sim.prefill_us(prompt_len) + generated as f64 * DECODE_STEP_ESTIMATE_US
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_is_symmetric_and_linear() {
        let m = HostTransferModel::default();
        assert_eq!(m.swap_out_us(4), m.swap_in_us(4));
        assert!((m.round_trip_us(4) - (2.0 * 20.0 + 2.0 * 4.0 * 10.0)).abs() < 1e-9);
        // More blocks strictly cost more.
        assert!(m.round_trip_us(8) > m.round_trip_us(4));
    }

    #[test]
    fn for_link_prices_blocks_by_bandwidth() {
        // 256 KiB blocks over a 25 GB/s PCIe-class link: ~10.5 µs/block,
        // recovering the default model's anchor.
        let m = HostTransferModel::for_link(20.0, 25.0, 256 * 1024);
        assert!((m.us_per_block - 10.486).abs() < 0.01, "{}", m.us_per_block);
        assert_eq!(m.base_us, 20.0);
        // Doubling bandwidth halves the wire cost; base is untouched.
        let fast = HostTransferModel::for_link(20.0, 50.0, 256 * 1024);
        assert!((fast.us_per_block * 2.0 - m.us_per_block).abs() < 1e-9);
        // Degenerate links are free per block.
        assert_eq!(HostTransferModel::for_link(5.0, f64::INFINITY, 256 * 1024).us_per_block, 0.0);
        assert_eq!(HostTransferModel::for_link(5.0, 0.0, 256 * 1024).us_per_block, 0.0);
    }

    #[test]
    fn recompute_scales_with_prompt_and_history() {
        let sim = Simulator::h100();
        let short = recompute_estimate_us(&sim, 100, 0);
        assert!((short - sim.prefill_us(100)).abs() < 1e-9);
        assert!(recompute_estimate_us(&sim, 100, 50) > short);
        assert!(recompute_estimate_us(&sim, 400, 0) > short);
    }

    #[test]
    fn crossover_favors_recompute_for_short_fresh_requests() {
        // The decision rule's intended shape: a request with little KV
        // (few blocks, short prompt, nothing generated) is cheaper to
        // recompute; a deep-decode request with a long context is
        // cheaper to swap.
        let m = HostTransferModel::default();
        let sim = Simulator::h100();
        // 64-token prompt, nothing generated, 5 blocks held.
        assert!(recompute_estimate_us(&sim, 64, 0) < m.round_trip_us(5));
        // 480-token prompt, 200 generated, 43 blocks held: recompute
        // would replay 200 decode steps — swap wins.
        assert!(m.round_trip_us(43) < recompute_estimate_us(&sim, 480, 200));
    }
}
