//! Cost-model constants, fitted to the paper's published measurements.
//!
//! The anchors (Table 1, Figure 3 of the paper; H100 SXM5, BF16, D = 128):
//!
//! | observation                                   | value    |
//! |-----------------------------------------------|----------|
//! | L_K = 128, s = 1 (1 KV block)                 |  9.56 µs |
//! | L_K = 512, s = 1 (4 KV blocks)                | 13.72 µs |
//! | L_K = 512, s = 3 (2 blocks/CTA + combine)     | 11.37 µs |
//! | L_K = 2048, H_KV = 1, efficiency-loop split   | 11.99 µs |
//! | L_K = 4096, H_KV = 1, efficiency-loop split   | 13.88 µs |
//! | Figure 3 plateau (s >= 3)                     | 11.2–11.5 µs |
//!
//! Fitting those: fixed overhead `t_launch + t_setup ≈ 8.04 µs` dominates
//! short decode (§3.1: "short sequence decoding is bounded by kernel launch
//! overhead and low occupancy"), per-KV-block streaming `t_block ≈ 1.42 µs`
//! (per-CTA latency-bound streaming; aggregate bandwidth scales ~linearly
//! over the ≤132-CTA range, far from the HBM3 roofline), and a split-combine
//! cost that grows with the number of non-empty partials — steeply to 4
//! partials (serial tail of the reduction kernel), shallowly beyond
//! (tree-parallel), plus a per-slot scan term for over-split launches.
//!
//! The resulting model lands every Table-1 row within ~10% absolute and
//! reproduces the ratios (1.21x/1.24x wins, 1.00x controls) — see
//! EXPERIMENTS.md for the side-by-side.

/// Tunable constants of the kernel latency model. All times in µs.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// Kernel launch + CUDA-Graph replay overhead (per launch).
    pub t_launch_us: f64,
    /// Grid setup: scheduler-metadata read, CTA prologue (per wave 0).
    pub t_setup_us: f64,
    /// Streaming one 128-token KV block (K+V, D = 128, BF16) through one
    /// CTA: latency-bound, so constant per CTA while the grid is small.
    pub t_block_us: f64,
    /// Split-combine: base cost of the reduction kernel (s > 1 only).
    pub combine_base_us: f64,
    /// Split-combine: per non-empty partial, up to 4 partials (serial tail).
    pub combine_near_us: f64,
    /// Split-combine: per non-empty partial beyond 4 (tree-parallel phase).
    pub combine_far_us: f64,
    /// Split-combine: per *commanded* split slot (LSE scan, incl. empties).
    pub combine_slot_us: f64,
    /// Split-combine: atomic-contention/wave-quantization penalty once the
    /// combine grid (nonempty × tiles CTAs) exceeds one SM wave — §5.3's
    /// "dense configurations where splitting introduces atomic combination
    /// overhead". Per excess wave-fraction, µs.
    pub combine_atomic_us: f64,
    /// Internal-heuristic (no precomputed metadata) path: fraction of the
    /// split benefit that is lost (§5.1's ~1.00–1.05x observation).
    pub internal_path_loss: f64,
    /// Relative measurement noise (std-dev) for the A/B harness jitter.
    pub noise_rel_std: f64,
    /// Reference KV block bytes the t_block constant was fitted at
    /// (128 tokens x D=128 x 2 bytes x {K,V}).
    pub ref_block_bytes: f64,
}

impl Calibration {
    /// Constants fitted to the paper's H100 SXM5 + FA3 measurements.
    pub fn paper_h100() -> Calibration {
        Calibration {
            t_launch_us: 6.60,
            t_setup_us: 1.44,
            t_block_us: 1.42,
            combine_base_us: 0.40,
            combine_near_us: 0.45,
            combine_far_us: 0.10,
            combine_slot_us: 0.003,
            combine_atomic_us: 6.0,
            internal_path_loss: 0.80,
            noise_rel_std: 0.004,
            ref_block_bytes: 2.0 * 128.0 * 128.0 * 2.0,
        }
    }

    /// Fixed per-launch overhead.
    pub fn overhead_us(&self) -> f64 {
        self.t_launch_us + self.t_setup_us
    }

    /// Per-KV-block streaming time scaled for head dim / dtype.
    pub fn t_block_scaled_us(&self, d: usize, dtype_bytes: usize) -> f64 {
        let block_bytes = 2.0 * 128.0 * d as f64 * dtype_bytes as f64;
        self.t_block_us * block_bytes / self.ref_block_bytes
    }

    /// Split-combine reduction cost for `nonempty` partials out of
    /// `commanded` split slots, across `tiles` (batch × kv-head) outputs
    /// on `sms` available SMs.
    pub fn combine_us(&self, nonempty: usize, commanded: usize, tiles: usize, sms: usize) -> f64 {
        if commanded <= 1 {
            return 0.0;
        }
        // Atomic/wave contention: the combine grid has nonempty × tiles
        // partial-reductions; past one full SM wave they serialize. The
        // upstream efficiency loop self-limits to ≤ 1 wave (its wave-
        // efficiency objective), so this term only punishes forced
        // over-splitting of dense grids — exactly §5.3's observation.
        let combine_ctas = nonempty * tiles;
        let excess = combine_ctas.saturating_sub(sms) as f64 / sms as f64;
        let near = nonempty.min(4).saturating_sub(2) as f64;
        let far = nonempty.saturating_sub(4) as f64;
        self.combine_base_us
            + self.combine_near_us * near
            + self.combine_far_us * far
            + self.combine_slot_us * commanded as f64
            + self.combine_atomic_us * excess
    }

    /// Overlay a `[calibration]` config section onto the paper fit:
    /// specified keys override, unspecified keys keep [`paper_h100`]
    /// values. (Lives here, not in `util/config`, so the dependency edge
    /// points downward: sim/ -> util/, never util/ -> sim/.)
    ///
    /// [`paper_h100`]: Calibration::paper_h100
    pub fn from_config(cfg: &crate::util::config::Config) -> anyhow::Result<Calibration> {
        let base = Calibration::paper_h100();
        let s = "calibration";
        Ok(Calibration {
            t_launch_us: cfg.f64_or(s, "t_launch_us", base.t_launch_us)?,
            t_setup_us: cfg.f64_or(s, "t_setup_us", base.t_setup_us)?,
            t_block_us: cfg.f64_or(s, "t_block_us", base.t_block_us)?,
            combine_base_us: cfg.f64_or(s, "combine_base_us", base.combine_base_us)?,
            combine_near_us: cfg.f64_or(s, "combine_near_us", base.combine_near_us)?,
            combine_far_us: cfg.f64_or(s, "combine_far_us", base.combine_far_us)?,
            combine_slot_us: cfg.f64_or(s, "combine_slot_us", base.combine_slot_us)?,
            combine_atomic_us: cfg.f64_or(s, "combine_atomic_us", base.combine_atomic_us)?,
            internal_path_loss: cfg.f64_or(s, "internal_path_loss", base.internal_path_loss)?,
            noise_rel_std: cfg.f64_or(s, "noise_rel_std", base.noise_rel_std)?,
            ref_block_bytes: cfg.f64_or(s, "ref_block_bytes", base.ref_block_bytes)?,
        })
    }
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration::paper_h100()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_matches_fit() {
        let c = Calibration::paper_h100();
        assert!((c.overhead_us() - 8.04).abs() < 1e-9);
    }

    #[test]
    fn config_overlay_keeps_defaults() {
        let c = crate::util::config::Config::parse(
            "[calibration]\nt_launch_us = 7.0\nnoise_rel_std = 0.01\n",
        )
        .unwrap();
        let cal = Calibration::from_config(&c).unwrap();
        assert_eq!(cal.t_launch_us, 7.0);
        assert_eq!(cal.noise_rel_std, 0.01);
        // Unspecified keys keep the paper fit.
        let base = Calibration::paper_h100();
        assert_eq!(cal.t_block_us, base.t_block_us);
        assert_eq!(cal.combine_atomic_us, base.combine_atomic_us);
    }

    #[test]
    fn block_time_scales_with_bytes() {
        let c = Calibration::paper_h100();
        assert!((c.t_block_scaled_us(128, 2) - c.t_block_us).abs() < 1e-12);
        assert!((c.t_block_scaled_us(64, 2) - c.t_block_us / 2.0).abs() < 1e-12);
        assert!((c.t_block_scaled_us(128, 4) - c.t_block_us * 2.0).abs() < 1e-12);
    }

    #[test]
    fn combine_cost_shape() {
        let c = Calibration::paper_h100();
        assert_eq!(c.combine_us(1, 1, 1, 132), 0.0); // no split, no combine
        let c2 = c.combine_us(2, 2, 1, 132);
        let c4 = c.combine_us(4, 4, 1, 132);
        let c16 = c.combine_us(16, 16, 1, 132);
        assert!(c2 < c4 && c4 < c16, "monotone in partials");
        // Steep to 4, shallow beyond (the 2048/4096 anchors need this).
        assert!((c4 - c2) > (c16 - c4) / 6.0);
        // Over-split slot scan: same partials, more slots, slightly pricier.
        assert!(c.combine_us(4, 64, 1, 132) > c.combine_us(4, 4, 1, 132));
    }

    #[test]
    fn atomic_contention_fires_only_past_one_wave() {
        let c = Calibration::paper_h100();
        // 4 partials x 32 tiles = 128 CTAs <= 132: no penalty.
        let fits = c.combine_us(4, 4, 32, 132);
        assert_eq!(fits, c.combine_us(4, 4, 1, 132));
        // 4 partials x 64 tiles = 256 CTAs: contention kicks in (§5.3's
        // dense-grid atomic-combination overhead).
        let dense = c.combine_us(4, 4, 64, 132);
        assert!(dense > fits + 4.0, "dense={dense:.2} fits={fits:.2}");
    }

    #[test]
    fn internal_path_loss_in_unit_range() {
        let c = Calibration::paper_h100();
        assert!((0.0..=1.0).contains(&c.internal_path_loss));
    }
}
