//! Decode traces: latency over a multi-step generation, aggregated to the
//! metric the evolutionary search optimizes — TPOT (Time per Output Token,
//! §3.1) — and to serving-style summaries.

use crate::heuristics::tiles::DecodeShape;
use crate::planner::{PlanCursor, Planner};
use crate::util::stats::Summary;

use super::kernel_model::Simulator;

/// One simulated autoregressive generation: decode `n_tokens` steps with a
/// KV cache growing from `prompt_len`.
#[derive(Debug, Clone)]
pub struct DecodeTrace {
    pub batch: usize,
    pub h_q: usize,
    pub h_kv: usize,
    pub d: usize,
    pub prompt_len: usize,
    pub n_tokens: usize,
}

/// Aggregate of a simulated trace.
#[derive(Debug, Clone)]
pub struct TraceSummary {
    /// Attention-kernel time per output token, µs (the TPOT component the
    /// paper's search minimized; framework overhead is policy-invariant).
    pub tpot_us: f64,
    pub total_us: f64,
    pub per_step: Summary,
}

impl DecodeTrace {
    /// The paper's §3.1 target workload: Llama-70B/TP-8-shaped chat decode,
    /// Batch = 1, short prompts.
    pub fn chat(prompt_len: usize, n_tokens: usize) -> DecodeTrace {
        DecodeTrace { batch: 1, h_q: 8, h_kv: 1, d: 128, prompt_len, n_tokens }
    }

    /// Run the trace through `planner` on `sim`, re-planning every step as
    /// the context grows — exactly what the serving scheduler does per
    /// decode step. The per-step decision rides a [`PlanCursor`] (decode
    /// is monotone, so 128 consecutive steps share one pinned decision;
    /// the planner's LRU is only touched at bucket crossings) — the same
    /// hot path the engine uses, which is what makes the evolutionary
    /// evaluator's millions of trace steps cheap.
    pub fn run(&self, sim: &Simulator, planner: &mut Planner) -> TraceSummary {
        let mut samples = Vec::new();
        self.run_with(sim, planner, &mut samples)
    }

    /// [`DecodeTrace::run`] into a caller-owned sample buffer (cleared
    /// first), so sweep harnesses running many traces reuse one
    /// allocation instead of a fresh Vec per trace.
    pub fn run_with(
        &self,
        sim: &Simulator,
        planner: &mut Planner,
        samples: &mut Vec<f64>,
    ) -> TraceSummary {
        assert!(self.n_tokens > 0, "empty trace");
        samples.clear();
        samples.reserve(self.n_tokens);
        let mut cursor = planner.cursor();
        let mut total = 0.0;
        for step in 0..self.n_tokens {
            let l_k = self.prompt_len + step + 1; // attend over cache incl. new token
            let shape = DecodeShape::decode(self.batch, l_k, self.h_q, self.h_kv, self.d);
            let plan = cursor.plan(planner, &shape);
            let t = sim.kernel_us(&plan.metadata);
            samples.push(t);
            total += t;
        }
        TraceSummary {
            tpot_us: total / self.n_tokens as f64,
            total_us: total,
            per_step: Summary::of(samples),
        }
    }

    /// Run with an externally-forced split count each step (sweep harness).
    pub fn run_forced(&self, sim: &Simulator, num_splits: usize) -> TraceSummary {
        let planner = Planner::standard(); // knobs only; the policy is bypassed
        let mut samples = Vec::with_capacity(self.n_tokens);
        let mut total = 0.0;
        for step in 0..self.n_tokens {
            let l_k = self.prompt_len + step + 1;
            let shape = DecodeShape::decode(self.batch, l_k, self.h_q, self.h_kv, self.d);
            let plan = planner.plan_forced(&shape, num_splits);
            let t = sim.kernel_us(&plan.metadata);
            samples.push(t);
            total += t;
        }
        TraceSummary {
            tpot_us: total / self.n_tokens as f64,
            total_us: total,
            per_step: Summary::of(&samples),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn patched_policy_improves_chat_tpot() {
        // A chat trace that decodes across the L_K = 385..512 boundary
        // bucket must get faster under the sequence-aware policy.
        let sim = Simulator::h100();
        let trace = DecodeTrace::chat(384, 128); // steps cover 385..512
        let std = trace.run(&sim, &mut Planner::standard());
        let pat = trace.run(&sim, &mut Planner::sequence_aware());
        let speedup = std.tpot_us / pat.tpot_us;
        assert!(speedup > 1.15, "speedup {speedup:.3}");
    }

    #[test]
    fn outside_bucket_identical() {
        let sim = Simulator::h100();
        let trace = DecodeTrace::chat(64, 64); // stays under L_K = 129..384
        let std = trace.run(&sim, &mut Planner::standard());
        let pat = trace.run(&sim, &mut Planner::sequence_aware());
        assert_eq!(std.tpot_us, pat.tpot_us);
    }

    #[test]
    fn tpot_is_mean_of_steps() {
        let sim = Simulator::h100();
        let trace = DecodeTrace::chat(100, 10);
        let s = trace.run(&sim, &mut Planner::standard());
        assert!((s.tpot_us - s.total_us / 10.0).abs() < 1e-9);
        assert_eq!(s.per_step.n, 10);
    }

    #[test]
    fn forced_split_sweep_consistent_with_policy() {
        let sim = Simulator::h100();
        let trace = DecodeTrace::chat(448, 32); // inside the nblk=4 bucket
        let forced3 = trace.run_forced(&sim, 3);
        let pat = trace.run(&sim, &mut Planner::sequence_aware());
        // The patched policy IS s=3 in this bucket.
        assert!((forced3.tpot_us - pat.tpot_us).abs() < 1e-9);
    }

    #[test]
    fn cursor_shields_the_cache_across_growing_contexts() {
        let sim = Simulator::h100();
        let trace = DecodeTrace::chat(0, 512); // crosses 4 nblk buckets
        let mut planner = Planner::sequence_aware();
        trace.run(&sim, &mut planner);
        // The trace's cursor refills once per bucket crossing (4 cold
        // lookups reach the LRU and miss); the other 508 steps never touch
        // the cache at all.
        let stats = planner.cache_stats();
        assert_eq!(stats.misses, 4, "{stats:?}"); // one per nblk bucket
        assert_eq!(stats.hits, 0, "cursor bypasses the LRU: {stats:?}");
    }

    #[test]
    fn run_with_reuses_the_sample_buffer_and_matches_run() {
        let sim = Simulator::h100();
        let trace = DecodeTrace::chat(100, 32);
        let fresh = trace.run(&sim, &mut Planner::sequence_aware());
        let mut samples = Vec::new();
        let with = trace.run_with(&sim, &mut Planner::sequence_aware(), &mut samples);
        assert_eq!(with.tpot_us, fresh.tpot_us);
        assert_eq!(samples.len(), 32);
        let cap = samples.capacity();
        trace.run_with(&sim, &mut Planner::sequence_aware(), &mut samples);
        assert_eq!(samples.capacity(), cap, "sample buffer reused");
    }
}
