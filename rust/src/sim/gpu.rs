//! GPU device descriptions for the simulator.

/// Static description of the accelerator the kernel is dispatched onto.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Streaming multiprocessors available to compute grids.
    pub num_sms: usize,
    /// Peak HBM bandwidth, GB/s (context for roofline notes; the calibrated
    /// per-CTA streaming constant already embeds achieved bandwidth).
    pub hbm_bw_gbps: f64,
    /// L2 capacity in bytes.
    pub l2_bytes: usize,
}

impl GpuSpec {
    /// NVIDIA H100 SXM5 — the paper's testbed: 132 SMs, HBM3 ~3.35 TB/s.
    pub fn h100_sxm() -> GpuSpec {
        GpuSpec { name: "H100-SXM5", num_sms: 132, hbm_bw_gbps: 3350.0, l2_bytes: 50 * 1024 * 1024 }
    }

    /// H100 PCIe variant (114 SMs) — used by the ablation benches to show
    /// the heuristic's SM-count sensitivity.
    pub fn h100_pcie() -> GpuSpec {
        GpuSpec { name: "H100-PCIe", num_sms: 114, hbm_bw_gbps: 2000.0, l2_bytes: 50 * 1024 * 1024 }
    }

    /// A100 SXM (108 SMs) — the prior generation the upstream heuristic was
    /// tuned on; included for the "hardware scale" ablation (§2.2 argues the
    /// static threshold overlooks the *scale* of H100).
    pub fn a100_sxm() -> GpuSpec {
        GpuSpec { name: "A100-SXM4", num_sms: 108, hbm_bw_gbps: 2039.0, l2_bytes: 40 * 1024 * 1024 }
    }

    /// SMs available once `sm_margin` is reserved for the combine scheduler.
    pub fn sms_with_margin(&self, sm_margin: usize) -> usize {
        self.num_sms.saturating_sub(sm_margin).max(1)
    }

    /// Build the simulator-facing spec from a planner device profile, so
    /// planning and simulation agree on the hardware by construction.
    pub fn from_profile(profile: &crate::planner::DeviceProfile) -> GpuSpec {
        GpuSpec {
            name: profile.name,
            num_sms: profile.num_sms,
            hbm_bw_gbps: profile.hbm_bw_gbps,
            l2_bytes: profile.l2_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h100_matches_paper_constants() {
        let g = GpuSpec::h100_sxm();
        assert_eq!(g.num_sms, 132); // §2.1
    }

    #[test]
    fn margin_clamps() {
        let g = GpuSpec::h100_sxm();
        assert_eq!(g.sms_with_margin(0), 132);
        assert_eq!(g.sms_with_margin(32), 100);
        assert_eq!(g.sms_with_margin(1000), 1);
    }

    #[test]
    fn profile_conversion_agrees_with_presets() {
        use crate::planner::DeviceProfile;
        assert_eq!(GpuSpec::from_profile(&DeviceProfile::H100_SXM), GpuSpec::h100_sxm());
        assert_eq!(GpuSpec::from_profile(&DeviceProfile::H100_PCIE), GpuSpec::h100_pcie());
        assert_eq!(GpuSpec::from_profile(&DeviceProfile::A100_SXM), GpuSpec::a100_sxm());
        assert_eq!(GpuSpec::from_profile(&DeviceProfile::H200_SXM).num_sms, 132);
    }
}
