//! The kernel latency model: one FA3 decode-attention launch on H100.
//!
//! Model (constants in [`super::Calibration`], rationale there):
//!
//! ```text
//! nblk  = ceil(L_K / 128)                    KV blocks
//! bps   = ceil(nblk / s)                     serial blocks per CTA
//! e     = ceil(nblk / bps)                   non-empty splits
//! ctas  = tiles * e                          active CTAs (empties exit fast)
//! waves = ceil(ctas / SMs)
//! T     = t_launch + t_setup
//!         + waves * bps * t_block(D, dtype)
//!         + combine(e, s)                    when s > 1
//! ```
//!
//! The internal-heuristic dispatch path (no precomputed scheduler metadata)
//! retains `internal_path_loss` of the split benefit unrealized (§5.1:
//! ~1.00–1.05x instead of 1.21–1.24x).

use crate::heuristics::{DispatchPath, SchedulerMetadata};
use crate::util::prng::Rng;

use super::calibration::Calibration;
use super::gpu::GpuSpec;

/// Dtype width for the simulated kernel (Table 1 is BF16).
pub const DTYPE_BYTES: usize = 2;

/// Timing breakdown of one simulated kernel launch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelTiming {
    pub total_us: f64,
    pub launch_us: f64,
    pub body_us: f64,
    pub combine_us: f64,
    /// CTAs that actually carry work (tiles x non-empty splits).
    pub active_ctas: usize,
    /// Wave count after quantization onto the SM budget.
    pub waves: usize,
    /// First-wave SM occupancy fraction (the §2.1 headline number).
    pub occupancy: f64,
}

/// Simulate one decode-attention launch described by `md`.
pub fn simulate_kernel(md: &SchedulerMetadata, gpu: &GpuSpec, cal: &Calibration) -> KernelTiming {
    let shape = &md.shape;
    let s = md.num_splits.max(1);
    let nblk = shape.nblk();
    let bps = nblk.div_ceil(s);
    let nonempty = nblk.div_ceil(bps);
    let tiles = shape.total_mblocks(md.pack_gqa);
    let active_ctas = tiles * nonempty;
    let sms = gpu.sms_with_margin(md.sm_margin);
    let waves = active_ctas.div_ceil(sms).max(1);

    let t_block = cal.t_block_scaled_us(shape.d, DTYPE_BYTES);
    let launch_us = cal.overhead_us();
    let body_us = waves as f64 * bps as f64 * t_block;
    let combine_us = cal.combine_us(nonempty, s, tiles, sms);

    let mut total_us = launch_us + body_us + combine_us;

    if md.path == DispatchPath::InternalHeuristic && s > 1 {
        // Late split decision: most of the benefit over s = 1 is lost.
        let unsplit = md.with_splits(1).with_path(DispatchPath::PrecomputedMetadata);
        let t1 = simulate_kernel(&unsplit, gpu, cal).total_us;
        if t1 > total_us {
            total_us += cal.internal_path_loss * (t1 - total_us);
        }
    }

    KernelTiming {
        total_us,
        launch_us,
        body_us,
        combine_us,
        active_ctas,
        waves,
        occupancy: (active_ctas as f64 / sms as f64).min(1.0),
    }
}

/// Convenience wrapper owning a GPU + calibration, with an optional
/// deterministic measurement-noise stream for the A/B harness (mirrors the
/// paper's CUDA-Graph-replay jitter).
#[derive(Debug, Clone)]
pub struct Simulator {
    pub gpu: GpuSpec,
    pub cal: Calibration,
}

impl Simulator {
    /// The calibrated H100 SXM5 model (the paper's hardware).
    pub fn h100() -> Simulator {
        Simulator { gpu: GpuSpec::h100_sxm(), cal: Calibration::paper_h100() }
    }

    /// Simulator for any planner device profile (the calibration constants
    /// were fitted on H100; other parts inherit them as an approximation).
    pub fn for_profile(profile: &crate::planner::DeviceProfile) -> Simulator {
        Simulator { gpu: GpuSpec::from_profile(profile), cal: Calibration::paper_h100() }
    }

    /// A simulator over an explicit GPU spec and calibration.
    pub fn new(gpu: GpuSpec, cal: Calibration) -> Simulator {
        Simulator { gpu, cal }
    }

    /// Noise-free latency of one launch.
    pub fn kernel(&self, md: &SchedulerMetadata) -> KernelTiming {
        simulate_kernel(md, &self.gpu, &self.cal)
    }

    /// Noise-free latency of one launch, µs.
    pub fn kernel_us(&self, md: &SchedulerMetadata) -> f64 {
        self.kernel(md).total_us
    }

    /// One "measured" sample with multiplicative gaussian jitter — what an
    /// interleaved A/B timing harness would observe per replay.
    pub fn kernel_us_noisy(&self, md: &SchedulerMetadata, rng: &mut Rng) -> f64 {
        let t = self.kernel_us(md);
        t * (1.0 + self.cal.noise_rel_std * rng.normal())
    }

    /// Bulk prompt-ingestion latency for one request, µs. Prefill is
    /// policy-invariant (the paper's change is decode-only), so a coarse
    /// affine model — launch overhead plus a per-token compute/IO slope —
    /// is enough for serving-level projections. Used by the sim execution
    /// backend.
    pub fn prefill_us(&self, prompt_len: usize) -> f64 {
        50.0 + 0.05 * prompt_len as f64
    }

    /// Prompt-ingestion latency when the leading `cached_tokens` of the
    /// prompt are already resident (a prefix-cache hit): only the
    /// remainder pays the per-token slope, the launch overhead stays.
    /// `cached_tokens = 0` is exactly [`Simulator::prefill_us`] — the
    /// no-sharing byte-identity the prefix-cache bench gates on.
    pub fn prefill_cached_us(&self, prompt_len: usize, cached_tokens: usize) -> f64 {
        self.prefill_us(prompt_len.saturating_sub(cached_tokens))
    }

    /// One bounded prefill chunk of `chunk_len` prompt tokens appended
    /// after `kv_prior` already-resident tokens, µs. Like
    /// [`Simulator::prefill_us`] this is policy-invariant; the extra
    /// term models the chunk's queries attending over the resident
    /// context (causal attention against prior KV — the cost Sarathi-
    /// style chunking pays for bounding step latency, on top of one
    /// launch overhead *per chunk* instead of per prompt). With
    /// `kv_prior = 0` and the whole prompt in one chunk this is exactly
    /// `prefill_us` — the chunk = ∞ timing identity.
    pub fn chunk_prefill_us(&self, chunk_len: usize, kv_prior: usize) -> f64 {
        self.prefill_us(chunk_len) + CHUNK_CONTEXT_US_PER_TOKEN * kv_prior as f64
    }
}

/// Per-resident-token attention slope of a prefill chunk (µs/token):
/// re-reading prior KV is pure bandwidth, far cheaper than the 0.05
/// compute/IO slope of ingesting a new token.
pub const CHUNK_CONTEXT_US_PER_TOKEN: f64 = 0.005;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::tiles::DecodeShape;
    use crate::heuristics::{SequenceAwarePolicy, StandardPolicy};
    use crate::planner::{Planner, PlannerBuilder};

    fn sim() -> Simulator {
        Simulator::h100()
    }

    #[test]
    fn chunked_prefill_cost_model() {
        let s = sim();
        // A first chunk with no resident context is exactly bulk prefill:
        // the chunk = ∞ timing identity.
        assert_eq!(s.chunk_prefill_us(512, 0), s.prefill_us(512));
        // Splitting a prompt costs extra launches plus the context reads.
        let whole = s.prefill_us(512);
        let halves = s.chunk_prefill_us(256, 0) + s.chunk_prefill_us(256, 256);
        assert!(halves > whole, "chunking is never free: {halves} vs {whole}");
        // Resident context is much cheaper than fresh ingestion.
        let resident = s.chunk_prefill_us(256, 256) - s.chunk_prefill_us(256, 0);
        let fresh = s.prefill_us(512) - s.prefill_us(256);
        assert!(resident < fresh / 2.0);
    }

    fn forced(l_k: usize, h_kv: usize, s: usize) -> SchedulerMetadata {
        Planner::standard()
            .plan_forced(&DecodeShape::decode(1, l_k, 8 * h_kv, h_kv, 128), s)
            .metadata
    }

    fn policy_md(std: bool, shape: &DecodeShape) -> SchedulerMetadata {
        let mut p = if std { Planner::standard() } else { Planner::sequence_aware() };
        p.plan(shape).metadata
    }

    /// The paper's Table-1 anchor latencies, within 11% absolute.
    #[test]
    fn absolute_anchors_close() {
        let sim = sim();
        let cases = [
            (128, 1, 1, 9.56),
            (256, 1, 1, 11.57),
            (384, 1, 1, 13.60),
            (512, 1, 1, 13.72),
            (512, 1, 3, 11.37),
            (512, 2, 3, 10.93),
        ];
        for (l_k, h_kv, s, paper_us) in cases {
            let got = sim.kernel_us(&forced(l_k, h_kv, s));
            let rel = (got - paper_us).abs() / paper_us;
            assert!(rel < 0.11, "l_k={l_k} s={s}: got {got:.2}, paper {paper_us}, rel {rel:.3}");
        }
    }

    /// The headline: policy-driven speedup at the boundary bucket is ~1.2x.
    #[test]
    fn boundary_speedup_matches_paper_band() {
        let sim = sim();
        for h_kv in [1, 2] {
            let shape = DecodeShape::decode(1, 512, 8 * h_kv, h_kv, 128);
            let t_std = sim.kernel_us(&policy_md(true, &shape));
            let t_pat = sim.kernel_us(&policy_md(false, &shape));
            let speedup = t_std / t_pat;
            assert!(
                (1.15..=1.30).contains(&speedup),
                "h_kv={h_kv}: speedup {speedup:.3} outside the paper band"
            );
        }
    }

    /// Controls: every non-target Table-1 row must be exactly 1.00x
    /// (both policies choose the same split ⇒ identical latency).
    #[test]
    fn controls_are_exactly_unchanged() {
        let sim = sim();
        for (l_k, h_kv) in
            [(128, 1), (128, 2), (128, 8), (256, 1), (384, 8), (512, 8), (2048, 1), (2048, 2), (2048, 8), (4096, 1), (4096, 8)]
        {
            let shape = DecodeShape::decode(1, l_k, 8 * h_kv, h_kv, 128);
            let t_std = sim.kernel_us(&policy_md(true, &shape));
            let t_pat = sim.kernel_us(&policy_md(false, &shape));
            assert_eq!(t_std, t_pat, "l_k={l_k} h_kv={h_kv}");
        }
    }

    /// Figure 3's shape: steep drop from s=1, then a plateau whose spread
    /// is small, with the paper's chosen s=3 inside it.
    #[test]
    fn ucurve_shape() {
        let sim = sim();
        let t1 = sim.kernel_us(&forced(512, 1, 1));
        let plateau: Vec<f64> =
            [2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64].iter().map(|&s| sim.kernel_us(&forced(512, 1, s))).collect();
        let lo = plateau.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = plateau.iter().cloned().fold(0.0, f64::max);
        assert!(t1 > hi, "s=1 ({t1:.2}) must sit above the plateau ({hi:.2})");
        assert!((t1 - hi) / t1 > 0.10, "steep drop expected");
        assert!((hi - lo) / lo < 0.08, "plateau spread should be shallow");
        // s = 3 vs the best point: within ~5% (paper: under ~2%).
        let t3 = sim.kernel_us(&forced(512, 1, 3));
        assert!((t3 - lo) / lo < 0.06, "s=3 must be near the plateau floor");
    }

    /// Long-context anchors ride the pre-existing efficiency loop; the
    /// absolute times must stay near the paper's 2048/4096 rows.
    #[test]
    fn long_context_anchors() {
        let sim = sim();
        for (l_k, h_kv, paper_us) in [(2048, 1, 11.99), (2048, 8, 12.73), (4096, 1, 13.88), (4096, 8, 15.05)] {
            let shape = DecodeShape::decode(1, l_k, 8 * h_kv, h_kv, 128);
            let md = policy_md(true, &shape);
            let got = sim.kernel_us(&md);
            let rel = (got - paper_us).abs() / paper_us;
            assert!(rel < 0.15, "l_k={l_k} h_kv={h_kv}: got {got:.2} vs paper {paper_us} ({rel:.3})");
        }
    }

    /// §5.1: the internal-heuristic path only realizes ~1.00–1.05x.
    #[test]
    fn internal_path_modest_gains() {
        let sim = sim();
        let shape = DecodeShape::llama70b_tp8(1, 512);
        let t_std = sim.kernel_us(&policy_md(true, &shape));
        let md_int = PlannerBuilder::policy(SequenceAwarePolicy)
            .dispatch_path(DispatchPath::InternalHeuristic)
            .build()
            .plan(&shape)
            .metadata;
        let speedup = t_std / sim.kernel_us(&md_int);
        assert!((1.0..=1.07).contains(&speedup), "internal-path speedup {speedup:.3}");
    }

    /// Wave quantization: grids beyond 132 CTAs take a second wave.
    #[test]
    fn wave_quantization() {
        let sim = sim();
        let planner = Planner::standard();
        // 256 tiles at s=1 ⇒ 2 waves.
        let shape = DecodeShape::decode(8, 512, 256, 32, 128);
        let t = sim.kernel(&planner.plan_forced(&shape, 1).metadata);
        assert_eq!(t.active_ctas, 256);
        assert_eq!(t.waves, 2);
        let one_wave =
            sim.kernel(&planner.plan_forced(&DecodeShape::decode(4, 512, 256, 32, 128), 1).metadata);
        assert_eq!(one_wave.waves, 1);
        assert!(t.total_us > one_wave.total_us);
    }

    /// Occupancy collapse (§2.1): 8 tiles unsplit ⇒ ~6%.
    #[test]
    fn occupancy_headline() {
        let sim = sim();
        let t = sim.kernel(&forced(512, 8, 1));
        assert!((0.05..0.07).contains(&t.occupancy), "occ={}", t.occupancy);
        assert_eq!(t.active_ctas, 8);
    }

    #[test]
    fn noise_is_small_and_deterministic() {
        let sim = sim();
        let md = forced(512, 1, 1);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = sim.kernel_us_noisy(&md, &mut r1);
        let b = sim.kernel_us_noisy(&md, &mut r2);
        assert_eq!(a, b);
        let clean = sim.kernel_us(&md);
        assert!((a - clean).abs() / clean < 0.05);
    }

    #[test]
    fn sm_margin_shrinks_budget_and_can_add_waves() {
        let sim = sim();
        let shape = DecodeShape::decode(4, 512, 256, 32, 128); // 128 tiles
        let t0 = sim.kernel(&Planner::standard().plan_forced(&shape, 1).metadata);
        let with_margin = PlannerBuilder::policy(StandardPolicy)
            .sm_margin(30)
            .build()
            .plan_forced(&shape, 1)
            .metadata;
        let t_margin = sim.kernel(&with_margin);
        assert_eq!(t0.waves, 1);
        assert_eq!(t_margin.waves, 2); // 128 CTAs on 102 SMs
        assert!(t_margin.total_us > t0.total_us);
    }
}
