//! [`SimBackend`]: the calibrated H100 latency model behind the
//! [`ExecutionBackend`] contract.
//!
//! No numerics run — tokens are synthetic but deterministic (a pure
//! function of the cache position, so both policies produce identical
//! streams and A/B comparisons isolate *timing*). Latency is the
//! `sim::Simulator` kernel model evaluated on the plan's scheduler
//! metadata, plus a per-step framework overhead; prompt ingestion uses the
//! policy-invariant bulk-prefill model ([`Simulator::prefill_us`]). The
//! engine integrates `elapsed_us` into its virtual clock
//! ([`BackendCaps::virtual_clock`]).

use anyhow::{Context, Result};

use crate::planner::LaunchPlan;
use crate::sim::Simulator;

use super::{
    snap_splits, validate_batch, BackendCaps, ExecutionBackend, PreparedStep, StepBatch,
    StepKind, StepOutcome,
};

/// Default per-step framework overhead, µs (sampler, scheduler, the
/// python-free launch path — small by construction).
pub const DEFAULT_FRAMEWORK_OVERHEAD_US: f64 = 2.0;

/// Simulated execution: virtual clock, synthetic tokens, faithful timing.
pub struct SimBackend {
    sim: Simulator,
    overhead_us: f64,
}

impl SimBackend {
    /// Wrap a simulator as an execution backend.
    pub fn new(sim: Simulator) -> SimBackend {
        SimBackend { sim, overhead_us: DEFAULT_FRAMEWORK_OVERHEAD_US }
    }

    /// The default H100 SXM5 model.
    pub fn h100() -> SimBackend {
        SimBackend::new(Simulator::h100())
    }

    /// A backend modeling any planner device profile — how the cluster
    /// fleet constructs per-replica backends (heterogeneous fleets mix
    /// profiles; planning and simulated timing agree by construction).
    pub fn for_profile(profile: &crate::planner::DeviceProfile) -> SimBackend {
        SimBackend::new(Simulator::for_profile(profile))
    }

    /// Override the per-step framework overhead.
    pub fn framework_overhead_us(mut self, us: f64) -> SimBackend {
        self.overhead_us = us;
        self
    }

    /// Deterministic synthetic token for a cache position (shared with the
    /// replay digest tests).
    pub fn synthetic_token(position: usize) -> i32 {
        (position % 1000) as i32
    }
}

impl ExecutionBackend for SimBackend {
    fn caps(&self) -> BackendCaps {
        BackendCaps {
            name: "sim",
            supports_pack_gqa: true,
            supports_metadata_path: true,
            virtual_clock: true,
        }
    }

    fn prepare(&mut self, batch: &StepBatch, plan: Option<&LaunchPlan>) -> Result<PreparedStep> {
        validate_batch(&self.caps(), batch, plan)?;
        // The simulator can price any split count: no artifact grid to
        // snap onto.
        let artifact_splits =
            plan.map(|p| snap_splits(&[], p.metadata.num_splits)).unwrap_or(1);
        Ok(PreparedStep {
            kind: batch.kind,
            bucket: batch.bucket,
            plan: plan.copied(),
            artifact_splits,
        })
    }

    /// Allocation-free on the decode path: the kernel model is scalar
    /// math and tokens land in the caller's reused `out.tokens` buffer —
    /// what keeps the engine's steady-state step at zero heap traffic.
    // pallas-lint: no_alloc
    fn execute(
        &mut self,
        batch: &StepBatch,
        step: &PreparedStep,
        out: &mut StepOutcome,
    ) -> Result<()> {
        out.reset();
        match step.kind {
            StepKind::Prefill => {
                // Prefill latency is policy-invariant (the paper's change
                // is decode-only): one bulk ingest per request. Tokens
                // whose KV already exists (a prefix-cache hit) are
                // skipped — the TTFT side of block-level sharing — while
                // the row still reports the FULL prompt as ingested, so
                // decode seeds at the full shared L_K.
                for row in &batch.rows {
                    out.elapsed_us +=
                        self.sim.prefill_cached_us(row.prompt.len(), row.cached_tokens);
                    out.prefilled.push((row.slot, row.prompt.len()));
                }
                out.chunk_wave_us = out.elapsed_us;
                out.prefill_calls = out.prefilled.len();
            }
            StepKind::Decode => {
                let plan = step.plan.as_ref().context("decode step lost its plan")?;
                // One attention launch per layer; 1 layer is the unit
                // (policy comparisons are ratios, layers scale both sides).
                out.elapsed_us = self.sim.kernel_us(&plan.metadata) + self.overhead_us;
                out.decode_wave_us = out.elapsed_us;
                for r in &batch.rows {
                    out.tokens.push((r.slot, SimBackend::synthetic_token(r.position)));
                }
            }
            StepKind::Mixed => {
                // Chunked prefill interleaved with decode: decode rows
                // (empty prompt) ride the planned wave priced exactly as
                // a decode step; each chunk row adds its policy-invariant
                // ingestion cost ([`Simulator::chunk_prefill_us`]) on top.
                // Tokens stay position-pure, so chunked and monolithic
                // schedules generate byte-identical streams.
                let mut decode_priced = false;
                for r in &batch.rows {
                    if r.prompt.is_empty() {
                        if !decode_priced {
                            let plan = step
                                .plan
                                .as_ref()
                                .context("mixed step's decode rows lost their plan")?;
                            let wave = self.sim.kernel_us(&plan.metadata) + self.overhead_us;
                            out.elapsed_us += wave;
                            out.decode_wave_us += wave;
                            decode_priced = true;
                        }
                        out.tokens.push((r.slot, SimBackend::synthetic_token(r.position)));
                    } else {
                        // `position` is the span start; report the new
                        // TOTAL ingested so the engine's chunk cursor
                        // (`prefilled`) advances to the span end.
                        let chunk = self.sim.chunk_prefill_us(r.prompt.len(), r.kv_len);
                        out.elapsed_us += chunk;
                        out.chunk_wave_us += chunk;
                        out.prefilled.push((r.slot, r.position + r.prompt.len()));
                        out.prefill_calls += 1;
                    }
                }
            }
        }
        Ok(())
    }

    fn release_slot(&mut self, _slot: usize) -> Result<()> {
        Ok(()) // no per-slot state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::tiles::DecodeShape;
    use crate::planner::Planner;
    use crate::backend::StepRow;

    fn decode_batch(n: usize, position: usize) -> StepBatch {
        StepBatch {
            kind: StepKind::Decode,
            rows: (0..n)
                .map(|slot| StepRow {
                    slot,
                    input_token: 5,
                    position,
                    kv_len: position,
                    prompt: Vec::new(),
                    cached_tokens: 0,
                })
                .collect(),
            bucket: n,
        }
    }

    #[test]
    fn decode_prices_the_plan_and_emits_synthetic_tokens() {
        let mut b = SimBackend::h100();
        let plan = Planner::sequence_aware().plan(&DecodeShape::llama70b_tp8(1, 512));
        let batch = decode_batch(2, 511);
        let prepared = b.prepare(&batch, Some(&plan)).unwrap();
        assert_eq!(prepared.artifact_splits, plan.metadata.num_splits);
        let mut out = StepOutcome::default();
        b.execute(&batch, &prepared, &mut out).unwrap();
        assert_eq!(out.tokens, vec![(0, 511), (1, 511)]);
        assert!(out.elapsed_us > DEFAULT_FRAMEWORK_OVERHEAD_US);
        assert!(out.prefilled.is_empty());
    }

    #[test]
    fn split_choice_moves_time_not_tokens() {
        let mut b = SimBackend::h100();
        let shape = DecodeShape::llama70b_tp8(1, 512);
        let run = |b: &mut SimBackend, plan: &crate::planner::LaunchPlan| {
            let batch = decode_batch(1, 511);
            let prepared = b.prepare(&batch, Some(plan)).unwrap();
            let mut out = StepOutcome::default();
            b.execute(&batch, &prepared, &mut out).unwrap();
            out
        };
        let std_out = run(&mut b, &Planner::standard().plan(&shape));
        let pat_out = run(&mut b, &Planner::sequence_aware().plan(&shape));
        assert_eq!(std_out.tokens, pat_out.tokens);
        assert!(std_out.elapsed_us > pat_out.elapsed_us, "patched should be faster here");
    }

    #[test]
    fn outcome_scratch_is_reset_between_steps() {
        // A stale outcome (previous step's tokens/prefills) must be fully
        // overwritten, not appended to — the engine reuses one buffer.
        let mut b = SimBackend::h100();
        let plan = Planner::sequence_aware().plan(&DecodeShape::llama70b_tp8(1, 512));
        let batch = decode_batch(1, 400);
        let prepared = b.prepare(&batch, Some(&plan)).unwrap();
        let mut out = StepOutcome {
            tokens: vec![(9, 9), (8, 8)],
            prefilled: vec![(7, 7)],
            elapsed_us: 123.0,
            prefill_calls: 5,
            decode_wave_us: 99.0,
            chunk_wave_us: 24.0,
        };
        // The one new token fits the existing capacity (2), so a reusing
        // execute must write into the SAME allocation — pointer identity,
        // not a capacity bound a fresh Vec could also satisfy.
        let ptr = out.tokens.as_ptr();
        b.execute(&batch, &prepared, &mut out).unwrap();
        assert_eq!(out.tokens, vec![(0, 400)]);
        assert!(out.prefilled.is_empty());
        assert_eq!(out.prefill_calls, 0);
        assert_eq!(out.tokens.as_ptr(), ptr, "scratch buffer must be reused, not replaced");
    }

    #[test]
    fn mixed_step_prices_decode_wave_plus_chunks() {
        let mut b = SimBackend::h100();
        let plan = Planner::sequence_aware().plan(&DecodeShape::llama70b_tp8(2, 512));
        let batch = StepBatch {
            kind: StepKind::Mixed,
            rows: vec![
                // Two decode rows share one wave price.
                StepRow { slot: 0, position: 511, kv_len: 511, ..StepRow::default() },
                StepRow { slot: 1, position: 300, kv_len: 300, ..StepRow::default() },
                // One chunk row: 32 prompt tokens after 64 resident.
                StepRow {
                    slot: 2,
                    position: 64,
                    kv_len: 64,
                    prompt: vec![7; 32],
                    ..StepRow::default()
                },
            ],
            bucket: 3,
        };
        let prepared = b.prepare(&batch, Some(&plan)).unwrap();
        let mut out = StepOutcome::default();
        b.execute(&batch, &prepared, &mut out).unwrap();
        // Decode rows emit position-pure tokens; the chunk row reports its
        // span end as the new ingestion total.
        assert_eq!(out.tokens, vec![(0, 511), (1, 300)]);
        assert_eq!(out.prefilled, vec![(2, 96)]);
        assert_eq!(out.prefill_calls, 1);
        let sim = Simulator::h100();
        let want = sim.kernel_us(&plan.metadata)
            + DEFAULT_FRAMEWORK_OVERHEAD_US
            + sim.chunk_prefill_us(32, 64);
        assert!((out.elapsed_us - want).abs() < 1e-9, "{} vs {want}", out.elapsed_us);
    }

    #[test]
    fn chunk_only_mixed_step_is_plan_free() {
        let mut b = SimBackend::h100();
        let batch = StepBatch {
            kind: StepKind::Mixed,
            rows: vec![StepRow {
                slot: 0,
                position: 0,
                kv_len: 0,
                prompt: vec![7; 64],
                ..StepRow::default()
            }],
            bucket: 1,
        };
        let prepared = b.prepare(&batch, None).unwrap();
        let mut out = StepOutcome::default();
        b.execute(&batch, &prepared, &mut out).unwrap();
        assert!(out.tokens.is_empty());
        assert_eq!(out.prefilled, vec![(0, 64)]);
        // A lone full-prompt chunk with no resident context costs exactly
        // bulk prefill: the chunk = ∞ timing identity at the backend level.
        assert_eq!(out.elapsed_us, Simulator::h100().prefill_us(64));
    }

    #[test]
    fn prefill_is_bulk_per_request() {
        let mut b = SimBackend::h100();
        let batch = StepBatch {
            kind: StepKind::Prefill,
            rows: vec![
                StepRow {
                    slot: 0,
                    input_token: 0,
                    position: 0,
                    kv_len: 0,
                    prompt: vec![1; 100],
                    cached_tokens: 0,
                },
                StepRow {
                    slot: 3,
                    input_token: 0,
                    position: 0,
                    kv_len: 0,
                    prompt: vec![2; 50],
                    cached_tokens: 0,
                },
            ],
            bucket: 4,
        };
        let prepared = b.prepare(&batch, None).unwrap();
        let mut out = StepOutcome::default();
        b.execute(&batch, &prepared, &mut out).unwrap();
        assert_eq!(out.prefilled, vec![(0, 100), (3, 50)]);
        assert_eq!(out.prefill_calls, 2);
        assert!(out.tokens.is_empty());
        assert!(out.elapsed_us > 100.0); // two bulk ingests' base cost
    }

    #[test]
    fn cached_prefix_tokens_cut_prefill_time_not_progress() {
        let run = |cached: usize| {
            let mut b = SimBackend::h100();
            let batch = StepBatch {
                kind: StepKind::Prefill,
                rows: vec![StepRow {
                    slot: 0,
                    input_token: 0,
                    position: 0,
                    kv_len: 0,
                    prompt: vec![1; 200],
                    cached_tokens: cached,
                }],
                bucket: 4,
            };
            let prepared = b.prepare(&batch, None).unwrap();
            let mut out = StepOutcome::default();
            b.execute(&batch, &prepared, &mut out).unwrap();
            out
        };
        let cold = run(0);
        let warm = run(192); // 12 shared blocks of 16
        // The hit cuts ingestion latency (TTFT), but the row still
        // reports the full prompt ingested: decode seeds at the full
        // shared L_K.
        assert!(warm.elapsed_us < cold.elapsed_us);
        assert_eq!(warm.prefilled, cold.prefilled);
        assert_eq!(warm.prefilled, vec![(0, 200)]);
    }
}
