//! [`ReplayBackend`]: record another backend's step outcomes and replay
//! them deterministically.
//!
//! Two modes:
//!
//! * **Record** — wraps an inner [`ExecutionBackend`], passes every call
//!   through, and appends a `(digest, outcome)` pair per executed step to
//!   a shared [`StepTrace`].
//! * **Replay** — serves recorded outcomes in order. Each `execute`
//!   digests the incoming `(StepBatch, PreparedStep)` pair and verifies it
//!   matches what was recorded; any divergence (different batch
//!   composition, split decision, or step order) fails loudly instead of
//!   silently replaying the wrong timing.
//!
//! Replay always reports a virtual clock (the recorded `elapsed_us` *is*
//! the time), so a trace recorded against the wall-clock PJRT backend
//! replays deterministically — the property the lifecycle test suite and
//! the serving soak gate are built on: same trace ⇒ identical
//! `EngineMetrics`.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::planner::LaunchPlan;

use super::{
    BackendCaps, BackendTopology, ExecutionBackend, PreparedStep, StepBatch, StepKind,
    StepOutcome,
};

/// The identity of one prepared step — everything that determines the
/// launch, cheap to compare.
#[derive(Debug, Clone, PartialEq)]
pub struct StepDigest {
    pub kind: StepKind,
    pub bucket: usize,
    pub artifact_splits: usize,
    /// The plan's requested split count (decode steps).
    pub num_splits: Option<usize>,
    /// Per row: (slot, input_token, position, kv_len, prompt_len,
    /// cached_tokens). Cached tokens are part of the identity because a
    /// prefix-cache hit changes a prefill step's modeled cost. For mixed
    /// chunked-prefill steps, (position, prompt_len) is exactly the chunk
    /// span, so chunk schedules replay deterministically with no extra
    /// fields.
    pub rows: Vec<(usize, i32, usize, usize, usize, usize)>,
}

impl StepDigest {
    /// Digest the `(batch, prepared)` pair an `execute` call receives —
    /// rows live in the batch, launch binding in the prepared step.
    pub fn of(batch: &StepBatch, step: &PreparedStep) -> StepDigest {
        StepDigest {
            kind: step.kind,
            bucket: step.bucket,
            artifact_splits: step.artifact_splits,
            num_splits: step.plan.as_ref().map(|p| p.metadata.num_splits),
            rows: batch
                .rows
                .iter()
                .map(|r| {
                    (r.slot, r.input_token, r.position, r.kv_len, r.prompt.len(), r.cached_tokens)
                })
                .collect(),
        }
    }
}

/// One recorded step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepRecord {
    pub digest: StepDigest,
    pub outcome: StepOutcome,
    /// Slots released between this step and the next.
    pub released: Vec<usize>,
}

/// A recorded run: the backend's identity plus every executed step.
#[derive(Debug, Clone, Default)]
pub struct StepTrace {
    pub source: Option<&'static str>,
    pub topology: Option<BackendTopology>,
    pub records: Vec<StepRecord>,
}

impl StepTrace {
    /// Number of recorded steps.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Whether the trace recorded no steps.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

enum Mode {
    Record { inner: Box<dyn ExecutionBackend>, trace: Arc<Mutex<StepTrace>> },
    Replay { trace: StepTrace, cursor: usize },
}

/// Record/replay execution backend.
pub struct ReplayBackend {
    mode: Mode,
}

impl ReplayBackend {
    /// Wrap `inner`, recording every executed step into the returned
    /// shared trace handle (lock it after the run to clone the trace out —
    /// the engine owns the backend box, so the trace must be shared).
    pub fn recorder(inner: Box<dyn ExecutionBackend>) -> (ReplayBackend, Arc<Mutex<StepTrace>>) {
        let trace = Arc::new(Mutex::new(StepTrace {
            source: Some(inner.caps().name),
            topology: inner.topology(),
            records: Vec::new(),
        }));
        (ReplayBackend { mode: Mode::Record { inner, trace: trace.clone() } }, trace)
    }

    /// Replay a recorded trace from the start.
    pub fn replay(trace: StepTrace) -> ReplayBackend {
        ReplayBackend { mode: Mode::Replay { trace, cursor: 0 } }
    }

    /// Steps consumed so far (replay mode).
    pub fn cursor(&self) -> usize {
        match &self.mode {
            Mode::Record { trace, .. } => trace.lock().unwrap().records.len(),
            Mode::Replay { cursor, .. } => *cursor,
        }
    }
}

impl ExecutionBackend for ReplayBackend {
    fn caps(&self) -> BackendCaps {
        match &self.mode {
            // Pass the inner backend's capabilities through so recording
            // doesn't change engine behavior.
            Mode::Record { inner, .. } => BackendCaps { name: "replay-rec", ..inner.caps() },
            // Replay owns time: the recorded elapsed_us is authoritative.
            Mode::Replay { .. } => BackendCaps {
                name: "replay",
                supports_pack_gqa: true,
                supports_metadata_path: true,
                virtual_clock: true,
            },
        }
    }

    fn topology(&self) -> Option<BackendTopology> {
        match &self.mode {
            Mode::Record { inner, .. } => inner.topology(),
            Mode::Replay { trace, .. } => trace.topology.clone(),
        }
    }

    fn prepare(&mut self, batch: &StepBatch, plan: Option<&LaunchPlan>) -> Result<PreparedStep> {
        let caps = self.caps();
        match &mut self.mode {
            Mode::Record { inner, .. } => inner.prepare(batch, plan),
            Mode::Replay { trace, cursor } => {
                // Bind the step exactly as recorded so digests line up even
                // if the replay engine snaps splits differently.
                super::validate_batch(&caps, batch, plan)?;
                let artifact_splits = trace
                    .records
                    .get(*cursor)
                    .map(|r| r.digest.artifact_splits)
                    .context("replay trace exhausted")?;
                Ok(PreparedStep {
                    kind: batch.kind,
                    bucket: batch.bucket,
                    plan: plan.copied(),
                    artifact_splits,
                })
            }
        }
    }

    fn execute(
        &mut self,
        batch: &StepBatch,
        step: &PreparedStep,
        out: &mut StepOutcome,
    ) -> Result<()> {
        match &mut self.mode {
            Mode::Record { inner, trace } => {
                let digest = StepDigest::of(batch, step);
                inner.execute(batch, step, out)?;
                trace.lock().unwrap().records.push(StepRecord {
                    digest,
                    outcome: out.clone(),
                    released: Vec::new(),
                });
                Ok(())
            }
            Mode::Replay { trace, cursor } => {
                let Some(record) = trace.records.get(*cursor) else {
                    bail!("replay trace exhausted after {} steps", trace.records.len())
                };
                let got = StepDigest::of(batch, step);
                if got != record.digest {
                    bail!(
                        "replay divergence at step {}: recorded {:?}, engine prepared {:?}",
                        *cursor,
                        record.digest,
                        got
                    );
                }
                *cursor += 1;
                // Copy the recorded outcome into the caller's scratch
                // (extend into the reused buffers rather than cloning
                // fresh Vecs).
                out.reset();
                out.tokens.extend_from_slice(&record.outcome.tokens);
                out.prefilled.extend_from_slice(&record.outcome.prefilled);
                out.elapsed_us = record.outcome.elapsed_us;
                out.prefill_calls = record.outcome.prefill_calls;
                Ok(())
            }
        }
    }

    fn release_slot(&mut self, slot: usize) -> Result<()> {
        match &mut self.mode {
            Mode::Record { inner, trace } => {
                inner.release_slot(slot)?;
                if let Some(last) = trace.lock().unwrap().records.last_mut() {
                    last.released.push(slot);
                }
                Ok(())
            }
            Mode::Replay { .. } => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{SimBackend, StepRow};
    use crate::heuristics::tiles::DecodeShape;
    use crate::planner::Planner;

    fn decode_batch(position: usize) -> StepBatch {
        StepBatch {
            kind: StepKind::Decode,
            rows: vec![StepRow {
                slot: 0,
                input_token: 9,
                position,
                kv_len: position,
                prompt: Vec::new(),
                cached_tokens: 0,
            }],
            bucket: 1,
        }
    }

    #[test]
    fn record_then_replay_reproduces_outcomes() {
        let (mut rec, trace) = ReplayBackend::recorder(Box::new(SimBackend::h100()));
        let plan = Planner::sequence_aware().plan(&DecodeShape::llama70b_tp8(1, 512));
        let mut recorded = Vec::new();
        let mut out = StepOutcome::default();
        for pos in [500usize, 501, 502] {
            let batch = decode_batch(pos);
            let p = rec.prepare(&batch, Some(&plan)).unwrap();
            rec.execute(&batch, &p, &mut out).unwrap();
            recorded.push(out.clone());
        }
        rec.release_slot(0).unwrap();
        let trace = trace.lock().unwrap().clone();
        assert_eq!(trace.len(), 3);
        assert_eq!(trace.records[2].released, vec![0]);

        let mut rep = ReplayBackend::replay(trace);
        for (i, pos) in [500usize, 501, 502].iter().enumerate() {
            let batch = decode_batch(*pos);
            let p = rep.prepare(&batch, Some(&plan)).unwrap();
            rep.execute(&batch, &p, &mut out).unwrap();
            assert_eq!(out, recorded[i]);
        }
        assert_eq!(rep.cursor(), 3);
    }

    #[test]
    fn divergence_is_detected() {
        let (mut rec, trace) = ReplayBackend::recorder(Box::new(SimBackend::h100()));
        let plan = Planner::standard().plan(&DecodeShape::llama70b_tp8(1, 512));
        let batch = decode_batch(100);
        let p = rec.prepare(&batch, Some(&plan)).unwrap();
        let mut out = StepOutcome::default();
        rec.execute(&batch, &p, &mut out).unwrap();
        let trace = trace.lock().unwrap().clone();

        let mut rep = ReplayBackend::replay(trace);
        // Different position => different digest => divergence error.
        let batch = decode_batch(101);
        let p = rep.prepare(&batch, Some(&plan)).unwrap();
        let err = rep.execute(&batch, &p, &mut out).unwrap_err();
        assert!(format!("{err:#}").contains("divergence"), "{err:#}");
    }

    #[test]
    fn exhausted_trace_errors() {
        let mut rep = ReplayBackend::replay(StepTrace::default());
        let plan = Planner::standard().plan(&DecodeShape::llama70b_tp8(1, 512));
        assert!(rep.prepare(&decode_batch(1), Some(&plan)).is_err());
    }
}
