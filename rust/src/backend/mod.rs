//! Execution backends: the contract between the serving engine and
//! whatever actually runs (or models) a decode step.
//!
//! Before this module the engine matched on a two-variant `EngineBackend`
//! enum (`Pjrt` | `Simulated`) at every call site — adding a backend meant
//! editing the step loop, and nothing could be tested against a fake. The
//! [`ExecutionBackend`] trait inverts that: the engine fills a
//! backend-agnostic [`StepBatch`] each step (into a scratch buffer it
//! reuses across steps — the zero-allocation decode hot path), asks the
//! backend to [`ExecutionBackend::prepare`] it against the planner's
//! [`LaunchPlan`] into a small Copy [`PreparedStep`] binding, then
//! [`ExecutionBackend::execute`]s the step into a caller-owned
//! [`StepOutcome`] scratch (tokens, prompt-ingestion progress, elapsed
//! time) and applies it to its own request state. No module outside
//! `backend/` knows which backend is running, and no buffer crosses the
//! trait by value.
//!
//! Three implementations:
//!
//! * [`SimBackend`]    — the calibrated H100 latency model on a virtual
//!                       clock; synthetic tokens, faithful timing,
//! * [`PjrtBackend`]   — real execution of the AOT artifacts on the CPU
//!                       PJRT client; true logits, wall-clock timing,
//! * [`ReplayBackend`] — records another backend's step outcomes into a
//!                       [`replay::StepTrace`] and replays them
//!                       deterministically (tests, soak benches).
//!
//! Invariants every backend upholds (see DESIGN.md §Serving engine and
//! §Decode hot path):
//!
//! 1. `prepare` is pure with respect to backend state *and* the batch: it
//!    validates against [`BackendCaps`] and snaps the plan onto what the
//!    backend can actually launch, but performs no KV-cache mutation and
//!    does not take the rows (they stay in the caller's scratch).
//! 2. `execute` runs exactly the `(batch, prepared)` pair `prepare` bound,
//!    resets `out` before writing, and reports `elapsed_us` on its own
//!    clock domain ([`BackendCaps::virtual_clock`] tells the engine
//!    which). Virtual-clock decode steps must not heap-allocate in steady
//!    state (the allocation-guard test holds the engine to zero).
//! 3. Per-slot KV state is dropped on [`ExecutionBackend::release_slot`],
//!    which the engine calls for every retirement *and* cancellation.

pub mod pjrt;
pub mod replay;
pub mod sim;

pub use pjrt::PjrtBackend;
pub use replay::{ReplayBackend, StepTrace};
pub use sim::SimBackend;

use anyhow::{bail, Result};

use crate::planner::LaunchPlan;

/// Model attention geometry a serving engine needs. Lives here (not in the
/// coordinator) because backends that own artifacts derive it themselves
/// and hand it up through [`BackendTopology`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnGeometry {
    pub h_q: usize,
    pub h_kv: usize,
    pub d: usize,
    pub max_seq: usize,
}

/// Capability flags a backend advertises. The engine adapts to these
/// instead of matching on the backend's type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendCaps {
    pub name: &'static str,
    /// Whether the backend can realize the packed-GQA tile layout.
    pub supports_pack_gqa: bool,
    /// Whether the backend accepts precomputed scheduler metadata (the
    /// paper's §5.1 deployment path). All built-ins do; a backend that
    /// doesn't would fall back to kernel-internal dispatch.
    pub supports_metadata_path: bool,
    /// True when `elapsed_us` is modeled (virtual) time the engine should
    /// integrate into its own clock; false when it is wall time.
    pub virtual_clock: bool,
}

/// What a backend knows about its own model/artifacts, if anything. A
/// backend bound to compiled artifacts (PJRT) derives this from its
/// manifest so the engine and the artifacts can't skew; model-free
/// backends (sim) return `None` and the engine's builder must supply the
/// geometry instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendTopology {
    pub geometry: AttnGeometry,
    /// Split variants the backend can actually launch (ascending, always
    /// containing 1). Empty means "any split count".
    pub available_splits: Vec<usize>,
    pub vocab: usize,
}

/// What kind of work a step carries. `Prefill` and `Decode` steps are
/// homogeneous (the legacy monolithic schedule); `Mixed` steps carry
/// bounded prefill chunks and decode rows in one wave (continuous
/// batching with chunked prefill — see DESIGN.md §Continuous batching).
/// Row kind inside a `Mixed` step is derived, not stored: a row with a
/// non-empty `prompt` is a chunk, an empty one decodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepKind {
    Prefill,
    Decode,
    Mixed,
}

/// One request row inside a step, described in backend-neutral terms.
/// `Default` is an empty decode row — the engine pools rows across steps
/// and refills them in place (the mixed-step zero-allocation path).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StepRow {
    /// KV-cache row (assigned at admission, stable for the request's life).
    pub slot: usize,
    /// Decode: the token fed to the model this step.
    pub input_token: i32,
    /// Decode: cache position the new token is written to (== current KV
    /// length). Prefill: tokens already ingested (resume point). Mixed
    /// chunk rows: the chunk span's first prompt offset — `(position,
    /// prompt.len())` IS the span, so step digests replay chunk schedules
    /// deterministically with no extra fields.
    pub position: usize,
    /// Current KV length of the row (for chunk rows: resident context the
    /// chunk's queries attend over, including prefix-cache-shared blocks).
    pub kv_len: usize,
    /// Prefill rows carry the full prompt, mixed chunk rows exactly their
    /// span of it; decode rows leave this empty.
    pub prompt: Vec<i32>,
    /// Prefill: leading prompt tokens whose KV already exists (the
    /// prefix-cache grant) — virtual-clock backends skip their ingestion
    /// cost; physical backends may re-ingest (the dense PJRT store holds
    /// no shared pages) without affecting correctness. Decode rows: 0.
    pub cached_tokens: usize,
}

/// The engine's per-step work description. The engine owns one as scratch
/// and refills it in place every step; `Default` is the empty scratch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StepBatch {
    pub kind: StepKind,
    pub rows: Vec<StepRow>,
    /// Artifact batch bucket the rows are packed into (>= rows.len()).
    /// Prefill steps ingest per-request and use the bucket only as a hint.
    pub bucket: usize,
}

impl Default for StepBatch {
    fn default() -> StepBatch {
        StepBatch { kind: StepKind::Decode, rows: Vec::new(), bucket: 0 }
    }
}

/// A validated, backend-accepted binding for one step: what `prepare`
/// hands to `execute` *alongside the batch it bound*. Plain Copy data —
/// the rows stay in the caller's [`StepBatch`] scratch, so the steady
/// state moves no buffers across the trait.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PreparedStep {
    pub kind: StepKind,
    pub bucket: usize,
    /// The planner's launch plan (decode steps on the metadata path).
    pub plan: Option<LaunchPlan>,
    /// The plan's split count snapped onto what this backend can launch
    /// (static artifact grids can't realize arbitrary `s`).
    pub artifact_splits: usize,
}

/// What a step produced. Caller-owned scratch: backends
/// [`StepOutcome::reset`] it and refill, so token/prefill buffers are
/// reused across steps.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepOutcome {
    /// `(slot, token)` for every row that emitted a token this step.
    pub tokens: Vec<(usize, i32)>,
    /// `(slot, prefilled)` for rows whose prompt-ingestion progressed.
    pub prefilled: Vec<(usize, usize)>,
    /// Time the step consumed on the backend's clock domain, µs.
    pub elapsed_us: f64,
    /// Model invocations performed for prompt ingestion this step.
    pub prefill_calls: usize,
    /// Share of `elapsed_us` attributed to the decode attention wave
    /// (0 when the step carried no decode rows, or when the backend
    /// doesn't decompose its cost — wall-clock backends report totals
    /// only). Feeds the flight recorder's per-wave cost counters.
    pub decode_wave_us: f64,
    /// Share of `elapsed_us` attributed to prompt ingestion (bulk
    /// prefill or mixed-step chunks); 0 under the same conditions.
    pub chunk_wave_us: f64,
}

impl StepOutcome {
    /// Clear for reuse (keeps buffer capacity).
    pub fn reset(&mut self) {
        self.tokens.clear();
        self.prefilled.clear();
        self.elapsed_us = 0.0;
        self.prefill_calls = 0;
        self.decode_wave_us = 0.0;
        self.chunk_wave_us = 0.0;
    }
}

/// The execution contract. `Send` because the engine (and therefore its
/// backend) moves onto a worker thread under `EngineHandle::spawn`.
pub trait ExecutionBackend: Send {
    fn caps(&self) -> BackendCaps;

    /// Model facts the backend derives from its own artifacts, if any.
    fn topology(&self) -> Option<BackendTopology> {
        None
    }

    /// Validate `batch` against this backend's capabilities and bind it to
    /// a launchable configuration. Read-only over the batch — the rows
    /// stay in the caller's scratch buffer, which it reuses across steps.
    /// Decode steps carry the planner's `plan`; prefill steps pass `None`
    /// (prefill latency is policy-invariant).
    fn prepare(&mut self, batch: &StepBatch, plan: Option<&LaunchPlan>) -> Result<PreparedStep>;

    /// Run one prepared step over `batch` (the same batch `prepare`
    /// bound), writing results into `out` (reset first; buffers are
    /// caller-owned scratch reused across steps).
    fn execute(
        &mut self,
        batch: &StepBatch,
        step: &PreparedStep,
        out: &mut StepOutcome,
    ) -> Result<()>;

    /// Drop per-slot KV state (request retired or cancelled).
    fn release_slot(&mut self, slot: usize) -> Result<()>;
}

/// Shared `prepare` validation: capability and shape checks every backend
/// applies before binding a step.
pub(crate) fn validate_batch(
    caps: &BackendCaps,
    batch: &StepBatch,
    plan: Option<&LaunchPlan>,
) -> Result<()> {
    if batch.rows.is_empty() {
        bail!("backend '{}': empty step batch", caps.name);
    }
    if batch.rows.len() > batch.bucket {
        bail!(
            "backend '{}': {} rows exceed bucket {}",
            caps.name,
            batch.rows.len(),
            batch.bucket
        );
    }
    match batch.kind {
        StepKind::Decode => {
            let Some(plan) = plan else {
                bail!("backend '{}': decode step without a launch plan", caps.name)
            };
            if plan.metadata.pack_gqa && !caps.supports_pack_gqa {
                bail!("backend '{}' does not support the packed-GQA layout", caps.name);
            }
        }
        StepKind::Prefill => {
            if plan.is_some() {
                bail!("backend '{}': prefill steps are plan-free", caps.name);
            }
            if batch.rows.iter().any(|r| r.prompt.is_empty()) {
                bail!("backend '{}': prefill row without a prompt", caps.name);
            }
        }
        StepKind::Mixed => {
            // Row kind is derived: non-empty prompt = chunk, empty =
            // decode. The plan covers exactly the decode wave.
            let decode_rows = batch.rows.iter().filter(|r| r.prompt.is_empty()).count();
            if decode_rows == batch.rows.len() {
                bail!(
                    "backend '{}': mixed step without a chunk row (use a decode step)",
                    caps.name
                );
            }
            match plan {
                Some(_) if decode_rows == 0 => {
                    bail!("backend '{}': chunk-only mixed steps are plan-free", caps.name);
                }
                None if decode_rows > 0 => {
                    bail!(
                        "backend '{}': mixed step's decode rows need a launch plan",
                        caps.name
                    );
                }
                Some(plan) if plan.metadata.pack_gqa && !caps.supports_pack_gqa => {
                    bail!("backend '{}' does not support the packed-GQA layout", caps.name);
                }
                _ => {}
            }
        }
    }
    Ok(())
}

/// Snap a requested split count onto the compiled variants: the largest
/// available split <= requested, falling back to 1 (same constraint as
/// CUDA-Graph-captured kernels in vLLM). An empty variant list means the
/// backend can realize any split count.
pub(crate) fn snap_splits(available: &[usize], requested: usize) -> usize {
    if available.is_empty() {
        return requested.max(1);
    }
    available.iter().copied().filter(|&s| s <= requested).next_back().unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::Planner;

    fn caps() -> BackendCaps {
        BackendCaps {
            name: "test",
            supports_pack_gqa: true,
            supports_metadata_path: true,
            virtual_clock: true,
        }
    }

    fn decode_row(slot: usize) -> StepRow {
        StepRow {
            slot,
            input_token: 1,
            position: 10,
            kv_len: 10,
            prompt: Vec::new(),
            cached_tokens: 0,
        }
    }

    #[test]
    fn snap_picks_largest_at_or_below() {
        assert_eq!(snap_splits(&[1, 3], 2), 1);
        assert_eq!(snap_splits(&[1, 3], 3), 3);
        assert_eq!(snap_splits(&[1, 3], 8), 3);
        assert_eq!(snap_splits(&[], 8), 8);
        assert_eq!(snap_splits(&[], 0), 1);
    }

    #[test]
    fn decode_requires_plan() {
        let batch =
            StepBatch { kind: StepKind::Decode, rows: vec![decode_row(0)], bucket: 1 };
        assert!(validate_batch(&caps(), &batch, None).is_err());
        let plan = Planner::sequence_aware()
            .plan(&crate::heuristics::tiles::DecodeShape::llama70b_tp8(1, 512));
        assert!(validate_batch(&caps(), &batch, Some(&plan)).is_ok());
    }

    #[test]
    fn pack_gqa_capability_enforced() {
        let mut c = caps();
        c.supports_pack_gqa = false;
        let batch =
            StepBatch { kind: StepKind::Decode, rows: vec![decode_row(0)], bucket: 1 };
        // Built-in planners use pack_gqa=true, which this backend refuses.
        let plan = Planner::standard()
            .plan(&crate::heuristics::tiles::DecodeShape::llama70b_tp8(1, 512));
        assert!(validate_batch(&c, &batch, Some(&plan)).is_err());
    }

    #[test]
    fn prefill_rows_need_prompts_and_no_plan() {
        let row = StepRow {
            slot: 0,
            input_token: 0,
            position: 0,
            kv_len: 0,
            prompt: vec![1, 2],
            cached_tokens: 0,
        };
        let ok = StepBatch { kind: StepKind::Prefill, rows: vec![row.clone()], bucket: 1 };
        assert!(validate_batch(&caps(), &ok, None).is_ok());
        let bad = StepBatch { kind: StepKind::Prefill, rows: vec![decode_row(0)], bucket: 1 };
        assert!(validate_batch(&caps(), &bad, None).is_err());
        let plan = Planner::standard()
            .plan(&crate::heuristics::tiles::DecodeShape::llama70b_tp8(1, 512));
        assert!(validate_batch(&caps(), &ok, Some(&plan)).is_err());
    }

    #[test]
    fn mixed_plan_covers_exactly_the_decode_wave() {
        let chunk_row = StepRow {
            slot: 1,
            input_token: 0,
            position: 64,
            kv_len: 64,
            prompt: vec![1; 32],
            cached_tokens: 0,
        };
        let plan = Planner::sequence_aware()
            .plan(&crate::heuristics::tiles::DecodeShape::llama70b_tp8(1, 512));
        // Chunk + decode rows: the decode wave needs its plan.
        let both = StepBatch {
            kind: StepKind::Mixed,
            rows: vec![decode_row(0), chunk_row.clone()],
            bucket: 2,
        };
        assert!(validate_batch(&caps(), &both, Some(&plan)).is_ok());
        assert!(validate_batch(&caps(), &both, None).is_err());
        // Chunk-only: plan-free, like prefill.
        let chunks_only =
            StepBatch { kind: StepKind::Mixed, rows: vec![chunk_row], bucket: 1 };
        assert!(validate_batch(&caps(), &chunks_only, None).is_ok());
        assert!(validate_batch(&caps(), &chunks_only, Some(&plan)).is_err());
        // No chunk row at all: that's a decode step, not a mixed one.
        let no_chunks =
            StepBatch { kind: StepKind::Mixed, rows: vec![decode_row(0)], bucket: 1 };
        assert!(validate_batch(&caps(), &no_chunks, Some(&plan)).is_err());
    }

    #[test]
    fn mixed_respects_pack_gqa_capability() {
        let mut c = caps();
        c.supports_pack_gqa = false;
        let batch = StepBatch {
            kind: StepKind::Mixed,
            rows: vec![
                decode_row(0),
                StepRow { prompt: vec![1; 8], ..StepRow::default() },
            ],
            bucket: 2,
        };
        let plan = Planner::standard()
            .plan(&crate::heuristics::tiles::DecodeShape::llama70b_tp8(1, 512));
        assert!(validate_batch(&c, &batch, Some(&plan)).is_err());
    }

    #[test]
    fn bucket_must_cover_rows() {
        let batch = StepBatch {
            kind: StepKind::Decode,
            rows: vec![decode_row(0), decode_row(1)],
            bucket: 1,
        };
        let plan = Planner::standard()
            .plan(&crate::heuristics::tiles::DecodeShape::llama70b_tp8(1, 512));
        assert!(validate_batch(&caps(), &batch, Some(&plan)).is_err());
    }
}
