//! [`PjrtBackend`]: real execution of the AOT artifacts on the CPU PJRT
//! client — true logits, true KV caches, wall-clock timing.
//!
//! Owns the dense KV cache pair the static-shape artifacts are compiled
//! against (the CUDA-Graph analog of paged attention: the block manager
//! upstream governs *admission*; this store is the *physical* cache) and
//! the (batch, splits) → artifact routing. Geometry, vocabulary, and the
//! compiled split variants all come from the manifest via
//! [`ExecutionBackend::topology`], so the engine and the artifacts can't
//! skew.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::planner::LaunchPlan;
use crate::runtime::{HostTensor, Registry};

use super::{
    snap_splits, validate_batch, AttnGeometry, BackendCaps, BackendTopology, ExecutionBackend,
    PreparedStep, StepBatch, StepKind, StepOutcome, StepRow,
};

/// Dense KV cache pair sized for the largest batch bucket.
struct CacheStore {
    n_layers: usize,
    max_batch: usize,
    max_seq: usize,
    h_kv: usize,
    d: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl CacheStore {
    fn new(n_layers: usize, max_batch: usize, max_seq: usize, h_kv: usize, d: usize) -> CacheStore {
        let n = n_layers * max_batch * max_seq * h_kv * d;
        CacheStore { n_layers, max_batch, max_seq, h_kv, d, k: vec![0.0; n], v: vec![0.0; n] }
    }

    fn row_elems(&self) -> usize {
        self.max_seq * self.h_kv * self.d
    }

    fn layer_stride(&self) -> usize {
        self.max_batch * self.row_elems()
    }

    /// True when `slots` are exactly rows 0..len in order AND the bucket
    /// width matches the store: gather/scatter degenerate to one straight
    /// memcpy of the whole store (DESIGN.md §Perf opt-2 — the steady-state
    /// case for a full batch, which is when the copies are largest).
    fn contiguous_full(&self, slots: &[usize], bucket: usize) -> bool {
        bucket == self.max_batch
            && slots.len() == bucket
            && slots.iter().enumerate().all(|(i, &s)| i == s)
    }

    /// Gather `slots` rows into bucket-shaped tensors (L, b, S, H, D).
    fn gather(&self, slots: &[usize], bucket: usize) -> (HostTensor, HostTensor) {
        assert!(slots.len() <= bucket);
        let shape = [self.n_layers, bucket, self.max_seq, self.h_kv, self.d];
        if self.contiguous_full(slots, bucket) {
            return (
                HostTensor::f32(&shape, self.k.clone()).unwrap(),
                HostTensor::f32(&shape, self.v.clone()).unwrap(),
            );
        }
        let row = self.row_elems();
        let mut k = vec![0.0f32; shape.iter().product()];
        let mut v = vec![0.0f32; shape.iter().product()];
        for l in 0..self.n_layers {
            for (bi, &slot) in slots.iter().enumerate() {
                let src = l * self.layer_stride() + slot * row;
                let dst = (l * bucket + bi) * row;
                k[dst..dst + row].copy_from_slice(&self.k[src..src + row]);
                v[dst..dst + row].copy_from_slice(&self.v[src..src + row]);
            }
        }
        (HostTensor::f32(&shape, k).unwrap(), HostTensor::f32(&shape, v).unwrap())
    }

    /// Scatter bucket-shaped tensors back into `slots` rows.
    fn scatter(&mut self, slots: &[usize], k: &HostTensor, v: &HostTensor) {
        let bucket = k.shape()[1];
        let kd = k.as_f32().unwrap();
        let vd = v.as_f32().unwrap();
        if self.contiguous_full(slots, bucket) {
            self.k.copy_from_slice(kd);
            self.v.copy_from_slice(vd);
            return;
        }
        let row = self.row_elems();
        for l in 0..self.n_layers {
            for (bi, &slot) in slots.iter().enumerate() {
                let dst = l * self.layer_stride() + slot * row;
                let src = (l * bucket + bi) * row;
                self.k[dst..dst + row].copy_from_slice(&kd[src..src + row]);
                self.v[dst..dst + row].copy_from_slice(&vd[src..src + row]);
            }
        }
    }

    fn clear_row(&mut self, slot: usize) {
        let row = self.row_elems();
        for l in 0..self.n_layers {
            let at = l * self.layer_stride() + slot * row;
            self.k[at..at + row].fill(0.0);
            self.v[at..at + row].fill(0.0);
        }
    }
}

/// Real-execution backend over loaded artifacts.
pub struct PjrtBackend {
    registry: Arc<Registry>,
    cache: CacheStore,
    geometry: AttnGeometry,
    splits: Vec<usize>,
    vocab: usize,
}

impl PjrtBackend {
    /// Build over a loaded registry. `max_batch` sizes the dense KV store
    /// and must match the engine's largest batch bucket
    /// (`BatcherConfig::max_batch`).
    pub fn new(registry: Arc<Registry>, max_batch: usize) -> Result<PjrtBackend> {
        let model = registry.manifest.model.as_ref().context("manifest has no model block")?;
        let geometry = AttnGeometry {
            h_q: model.config.n_heads_q,
            h_kv: model.config.n_heads_kv,
            d: model.config.head_dim,
            max_seq: model.config.max_seq,
        };
        let cache = CacheStore::new(
            model.config.n_layers,
            max_batch,
            geometry.max_seq,
            geometry.h_kv,
            geometry.d,
        );
        let vocab = model.config.vocab;
        let splits = registry.manifest.decode_split_variants();
        Ok(PjrtBackend { registry, cache, geometry, splits, vocab })
    }

    fn prefill_one(&mut self, row: &StepRow) -> Result<usize> {
        let p_len = row.prompt.len();
        let entry = self.registry.manifest.find_prefill_bucket(1, p_len).cloned();
        if let Some(entry) = entry {
            let b = entry.meta.batch.unwrap();
            let bucket_p = entry.meta.prompt_len.unwrap();
            let (kv_k, kv_v) = self.cache.gather(&[row.slot], b);
            let mut tokens = vec![0i32; b * bucket_p];
            tokens[..p_len].copy_from_slice(&row.prompt);
            let mut lens = vec![1i32; b]; // padded rows: 1 token, ignored
            lens[0] = p_len as i32;
            let out = self.registry.execute_model(
                &entry.name,
                &[
                    HostTensor::s32(&[b, bucket_p], tokens)?,
                    HostTensor::s32(&[b], lens)?,
                    kv_k,
                    kv_v,
                ],
            )?;
            self.cache.scatter(&[row.slot], &out[1], &out[2]);
            Ok(1)
        } else {
            // No prefill bucket fits: ingest via the decode path token by
            // token (slow correctness path; exercised by tests with tiny
            // buckets). The s=1 artifact always exists and splitting is
            // pure scheduling, so the split decision is irrelevant here.
            self.prefill_via_decode(row)
        }
    }

    fn prefill_via_decode(&mut self, row: &StepRow) -> Result<usize> {
        let entry = self
            .registry
            .manifest
            .find_decode_bucket(1, 1)
            .context("no decode bucket for prefill-via-decode")?
            .clone();
        let b = entry.meta.batch.unwrap();
        let mut calls = 0;
        for (t, &tok) in row.prompt.iter().enumerate().skip(row.position) {
            let (kv_k, kv_v) = self.cache.gather(&[row.slot], b);
            let mut toks = vec![0i32; b];
            toks[0] = tok;
            let mut pos = vec![0i32; b];
            pos[0] = t as i32;
            let out = self.registry.execute_model(
                &entry.name,
                &[HostTensor::s32(&[b], toks)?, HostTensor::s32(&[b], pos)?, kv_k, kv_v],
            )?;
            self.cache.scatter(&[row.slot], &out[1], &out[2]);
            calls += 1;
        }
        Ok(calls)
    }

    /// Decode one batch, pushing `(slot, token)` pairs into `emitted`
    /// (already reset by `execute`). Rows come from the caller's batch
    /// scratch; this backend allocates per call regardless (host tensors,
    /// gather/scatter) — it is the wall-clock path, not the modeled one.
    fn decode_batch(
        &mut self,
        batch: &StepBatch,
        step: &PreparedStep,
        emitted: &mut Vec<(usize, i32)>,
    ) -> Result<()> {
        let entry = self
            .registry
            .manifest
            .find_decode_bucket(step.bucket, step.artifact_splits)
            .or_else(|| self.registry.manifest.find_decode_bucket(step.bucket, 1))
            .with_context(|| format!("no decode bucket for b={}", step.bucket))?
            .clone();
        let b = entry.meta.batch.unwrap();
        if batch.rows.len() > b {
            bail!("bucket {b} smaller than batch {}", batch.rows.len());
        }
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let slots: Vec<usize> = batch.rows.iter().map(|r| r.slot).collect();
        for (bi, row) in batch.rows.iter().enumerate() {
            tokens[bi] = row.input_token;
            positions[bi] = row.position as i32;
        }
        let (kv_k, kv_v) = self.cache.gather(&slots, b);
        let out = self.registry.execute_model(
            &entry.name,
            &[HostTensor::s32(&[b], tokens)?, HostTensor::s32(&[b], positions)?, kv_k, kv_v],
        )?;
        self.cache.scatter(&slots, &out[1], &out[2]);
        let logits = out[0].as_f32()?;
        emitted.reserve(batch.rows.len());
        for (bi, row) in batch.rows.iter().enumerate() {
            let dist = &logits[bi * self.vocab..(bi + 1) * self.vocab];
            emitted.push((row.slot, argmax(dist) as i32));
        }
        Ok(())
    }
}

impl ExecutionBackend for PjrtBackend {
    fn caps(&self) -> BackendCaps {
        BackendCaps {
            name: "pjrt",
            supports_pack_gqa: true,
            supports_metadata_path: true,
            virtual_clock: false,
        }
    }

    fn topology(&self) -> Option<BackendTopology> {
        Some(BackendTopology {
            geometry: self.geometry,
            available_splits: self.splits.clone(),
            vocab: self.vocab,
        })
    }

    fn prepare(&mut self, batch: &StepBatch, plan: Option<&LaunchPlan>) -> Result<PreparedStep> {
        validate_batch(&self.caps(), batch, plan)?;
        if batch.kind == StepKind::Mixed {
            // The AOT artifact set compiles homogeneous prefill/decode
            // entry points; a fused chunk+decode kernel doesn't exist
            // yet. Fail at binding time, not mid-execution.
            bail!(
                "pjrt backend cannot launch mixed chunked-prefill steps \
                 (no fused artifact); use the sim backend or --chunk-tokens 0"
            );
        }
        let artifact_splits =
            plan.map(|p| snap_splits(&self.splits, p.metadata.num_splits)).unwrap_or(1);
        if batch.rows.iter().any(|r| r.slot >= self.cache.max_batch) {
            bail!("slot exceeds the KV store's {} rows", self.cache.max_batch);
        }
        Ok(PreparedStep {
            kind: batch.kind,
            bucket: batch.bucket,
            plan: plan.copied(),
            artifact_splits,
        })
    }

    fn execute(
        &mut self,
        batch: &StepBatch,
        step: &PreparedStep,
        out: &mut StepOutcome,
    ) -> Result<()> {
        out.reset();
        let t0 = Instant::now();
        match step.kind {
            StepKind::Prefill => {
                // `row.cached_tokens` is deliberately ignored here: the
                // dense per-slot KV store holds no shared pages, so a
                // prefix-cache hit cannot skip physical ingestion —
                // correctness over projection (the sim backend models
                // the timing win).
                let mut calls = 0;
                for row in &batch.rows {
                    calls += self.prefill_one(row)?;
                    out.prefilled.push((row.slot, row.prompt.len()));
                }
                out.prefill_calls = calls;
            }
            StepKind::Decode => {
                self.decode_batch(batch, step, &mut out.tokens)?;
            }
            // Unreachable: `prepare` rejects mixed batches for this
            // backend, and `execute` only runs prepared steps.
            StepKind::Mixed => bail!("pjrt: mixed step was never prepared"),
        }
        out.elapsed_us = t0.elapsed().as_micros() as f64;
        Ok(())
    }

    fn release_slot(&mut self, slot: usize) -> Result<()> {
        if slot >= self.cache.max_batch {
            bail!("release of slot {slot} beyond the KV store");
        }
        self.cache.clear_row(slot);
        Ok(())
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_store_gather_scatter_roundtrip() {
        let mut c = CacheStore::new(2, 3, 4, 1, 2);
        // Write a recognizable pattern into slot 1 via scatter.
        let shape = [2usize, 1, 4, 1, 2];
        let n: usize = shape.iter().product();
        let k = HostTensor::f32(&shape, (0..n).map(|i| i as f32).collect()).unwrap();
        let v = HostTensor::f32(&shape, (0..n).map(|i| (i as f32) * 10.0).collect()).unwrap();
        c.scatter(&[1], &k, &v);
        let (gk, gv) = c.gather(&[1], 1);
        assert_eq!(gk.as_f32().unwrap(), k.as_f32().unwrap());
        assert_eq!(gv.as_f32().unwrap(), v.as_f32().unwrap());
        // Other slots stay zero.
        let (g0, _) = c.gather(&[0], 1);
        assert!(g0.as_f32().unwrap().iter().all(|&x| x == 0.0));
        // clear_row zeroes slot 1 again.
        c.clear_row(1);
        let (g1, _) = c.gather(&[1], 1);
        assert!(g1.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn contiguous_full_fast_path_matches_slow_path() {
        let mut c = CacheStore::new(1, 2, 2, 1, 1);
        let shape = [1usize, 2, 2, 1, 1];
        let k = HostTensor::f32(&shape, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let v = HostTensor::f32(&shape, vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        assert!(c.contiguous_full(&[0, 1], 2));
        c.scatter(&[0, 1], &k, &v);
        let (gk, gv) = c.gather(&[0, 1], 2);
        assert_eq!(gk.as_f32().unwrap(), k.as_f32().unwrap());
        assert_eq!(gv.as_f32().unwrap(), v.as_f32().unwrap());
        // Non-contiguous selection reads the same data row-wise.
        let (g1, _) = c.gather(&[1], 1);
        assert_eq!(g1.as_f32().unwrap(), &[3.0, 4.0]);
    }
}
