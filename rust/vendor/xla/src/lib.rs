//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The runtime layer (`fa3_split::runtime`) is written against the real
//! XLA PJRT bindings, but this build environment has neither crates.io
//! access nor the native XLA libraries. This crate provides the exact API
//! surface the runtime uses so everything *compiles* unchanged; every
//! entry point that would touch PJRT returns an [`Error`] at runtime
//! (`PjRtClient::cpu()` fails first, so nothing downstream is reachable).
//!
//! The integration tests and benches that need real execution already
//! skip when `artifacts/manifest.json` is absent, so `cargo test` stays
//! green with this stub. To run the real path, replace this vendored crate
//! with the actual bindings in `rust/Cargo.toml` — no source changes are
//! needed in `fa3_split`.

use std::fmt;

/// Error type standing in for the bindings' status/error enum.
#[derive(Debug, Clone)]
pub struct Error {
    pub msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error {
        msg: "XLA/PJRT is unavailable in this build (offline stub — see rust/vendor/xla); \
              run `make artifacts` against a real xla crate to execute artifacts"
            .to_string(),
    }
}

/// Element types of XLA literals (subset ordering is irrelevant here; the
/// runtime only constructs F32 and S32).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host-native element types accepted by the typed buffer/literal APIs.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
}

/// Host-side literal (unconstructible through the stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Array shape of a literal.
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Device-resident buffer (unconstructible through the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// In the real bindings this creates the CPU PJRT client; the stub
    /// always fails, which is the single choke point keeping the rest of
    /// the stub unreachable.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation built from a proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.msg.contains("offline stub"));
        assert!(format!("{err:?}").contains("offline stub"));
    }

    #[test]
    fn computation_construction_is_possible() {
        // `XlaComputation::from_proto` is infallible in the real API, so the
        // stub must mirror that even though no proto can be loaded.
        assert!(HloModuleProto::from_text_file("/nonexistent").is_err());
    }
}
