//! Offline shim for the `anyhow` crate.
//!
//! The build container has no crates.io access, so this vendored crate
//! provides exactly the subset of anyhow's API the workspace uses:
//!
//! * [`Error`] — a message-chain error type (`Display`, `{:#}` alternate
//!   formatting that joins the context chain, `Debug` with `Caused by:`),
//! * [`Result`] — `Result<T, Error>` with the same defaulted type param,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Mirroring the real crate, [`Error`] intentionally does NOT implement
//! `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
//! conversion coherent with the reflexive `From<Error>` the `?` operator
//! uses. Swap this shim for the real `anyhow = "1"` by editing
//! `rust/Cargo.toml` if a networked build is ever available — no source
//! changes required.

use std::fmt;

/// Chain-of-messages error. `chain[0]` is the outermost context, the last
/// element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(..)` attaches).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, "outer: inner: root".
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — plain `Result` with the error defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

// Mirrors anyhow's `ext::StdError` trick: a private conversion trait with
// a blanket impl for real std errors plus a concrete impl for `Error`
// itself, so one `Context` impl covers both `Result<T, io::Error>` and
// `Result<T, anyhow::Error>`. The impls are disjoint because `Error` does
// not implement `std::error::Error` (same coherence argument the real
// crate relies on).
mod ext {
    use super::Error;

    pub trait IntoError {
        fn into_anyhow(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_anyhow(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_anyhow(self) -> Error {
            self
        }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an [`Error`] unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }

    #[test]
    fn option_and_result_context() {
        let none: Option<u32> = None;
        let e = none.context("absent").unwrap_err();
        assert_eq!(format!("{e}"), "absent");

        let r: Result<u32, std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: missing file");

        // Stacking context on an anyhow::Result.
        let r2: Result<u32> = Err(anyhow!("inner"));
        let e2 = r2.context("outer").unwrap_err();
        assert_eq!(format!("{e2:#}"), "outer: inner");
    }

    #[test]
    fn macros() {
        fn fails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(fails(3).unwrap(), 3);
        assert_eq!(format!("{}", fails(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", fails(7).unwrap_err()), "unlucky 7");
        let from_string = anyhow!(String::from("owned"));
        assert_eq!(format!("{from_string}"), "owned");
    }

    #[test]
    fn question_mark_conversion() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
