//! Bench: cluster-scale TP sweep — the occupancy gap widening as tensor
//! parallelism shrinks per-shard head count.
//!
//! The paper measures a single device; this harness measures the *cluster
//! decision that produces the paper's regime*. A fixed 8-KV-head GQA model
//! (Llama-3.1-70B attention shape) is served by a fleet at tp ∈ {1,2,4,8}:
//! per-shard `H_KV = 8/tp`, so the B=1 decode tile count walks 8 → 1 and
//! crosses the sequence-aware policy's `tiles < 4` window between tp=2 and
//! tp=4. Expected shape (deterministic sim):
//!
//! * tp=1, tp=2 — tiles ≥ 4: both policies plan identically, speedup 1.00x,
//! * tp=4, tp=8 — tiles < 4 in the L_K=385..512 bucket: the override fires,
//!   TPOT speedup ~1.15–1.25x, per-replica occupancy roughly doubles,
//! * batched sweep (max_batch=4) — the window additionally depends on the
//!   live batch (`tiles = B × H_KV_shard`), so the advantage grows
//!   *strictly* from tp=4 (fires only at B=1) to tp=8 (fires at B ≤ 3).
//!
//! A router comparison at tp=8 closes the loop: session-affinity keeps
//! every session single-replica, least-loaded minimizes imbalance.
//!
//! Run: `cargo bench --bench cluster_scale [-- --json PATH]`
//! (`BENCH_cluster_scale.json` is regenerated with `--json`.)

use fa3_split::backend::AttnGeometry;
use fa3_split::cluster::{
    router, ClusterTopology, Fleet, FleetConfig, FleetReport, Router, TpConfig,
};
use fa3_split::coordinator::{BatcherConfig, EngineConfig};
use fa3_split::planner::DeviceProfile;
use fa3_split::util::json::Json;
use fa3_split::util::table::{speedup, us, Align, Table};
use fa3_split::workload::ChatWorkload;

/// Full-model attention geometry (Llama-3.1-70B: 64 Q heads, 8 KV heads).
const MODEL: AttnGeometry = AttnGeometry { h_q: 64, h_kv: 8, d: 128, max_seq: 1024 };
const TP_DEGREES: [usize; 4] = [1, 2, 4, 8];
const REPLICAS: usize = 2;

/// Heavy-decode chat: the shared boundary-bucket regime (prompts pinned
/// to [385, 448] so every decode trajectory traverses the L_K=385..512
/// bucket; trajectories still spill beyond 512 into control territory).
fn heavy_decode(seed: u64, n_requests: usize) -> ChatWorkload {
    ChatWorkload::boundary_bucket(seed, n_requests, 96)
}

fn engine_cfg(max_batch: usize) -> EngineConfig {
    EngineConfig { batcher: BatcherConfig::for_max_batch(max_batch), ..Default::default() }
}

fn run_fleet(
    tp: usize,
    policy: &str,
    router: Box<dyn Router>,
    workload: &ChatWorkload,
    replicas: usize,
    max_batch: usize,
) -> FleetReport {
    let topology = ClusterTopology::builder(MODEL)
        .tp(TpConfig::new(tp))
        .replicas(replicas, DeviceProfile::H100_SXM)
        .build()
        .expect("valid sweep topology");
    let mut fleet = Fleet::new(
        topology,
        router,
        FleetConfig::default().policy(policy).engine(engine_cfg(max_batch)),
    )
    .expect("fleet builds");
    fleet.run(&workload.generate()).expect("fleet run completes")
}

/// One TP point: the same workload under both policies.
struct SweepRow {
    tp: usize,
    shard_h_kv: usize,
    std: FleetReport,
    seq: FleetReport,
}

impl SweepRow {
    fn tpot_mean(report: &FleetReport) -> f64 {
        report.tpot.as_ref().map(|s| s.mean).unwrap_or(0.0)
    }

    /// Sequence-aware advantage: standard-TPOT / sequence-aware-TPOT.
    fn advantage(&self) -> f64 {
        let (a, b) = (Self::tpot_mean(&self.std), Self::tpot_mean(&self.seq));
        if b > 0.0 {
            a / b
        } else {
            0.0
        }
    }
}

fn sweep(max_batch: usize, n_requests: usize, seed: u64) -> Vec<SweepRow> {
    TP_DEGREES
        .iter()
        .map(|&tp| {
            let workload = heavy_decode(seed, n_requests);
            let std = run_fleet(
                tp,
                "standard",
                Box::new(router::RoundRobin::new()),
                &workload,
                REPLICAS,
                max_batch,
            );
            let seq = run_fleet(
                tp,
                "sequence-aware",
                Box::new(router::RoundRobin::new()),
                &workload,
                REPLICAS,
                max_batch,
            );
            SweepRow { tp, shard_h_kv: MODEL.h_kv / tp, std, seq }
        })
        .collect()
}

/// Router comparison at the sharpest point (tp=8, sequence-aware): Poisson
/// traffic in multi-turn sessions across 4 replicas.
fn router_comparison() -> Vec<FleetReport> {
    ["round-robin", "least-loaded", "session-affinity"]
        .into_iter()
        .map(|name| {
            let workload = ChatWorkload {
                mean_gap_us: 1_200,
                turns_per_session: 4,
                ..heavy_decode(0xC3, 32)
            };
            run_fleet(8, "sequence-aware", router::by_name(name).expect("known"), &workload, 4, 2)
        })
        .collect()
}

/// The acceptance gate (also mirrored in tests/cluster_fleet.rs): the
/// sequence-aware advantage must never regress and must widen as sharding
/// shrinks head count.
fn verify(b1: &[SweepRow], batched: &[SweepRow], routers: &[FleetReport]) -> Result<(), String> {
    for rows in [b1, batched] {
        for r in rows {
            if r.advantage() < 0.999 {
                return Err(format!("tp={}: sequence-aware regressed ({:.3}x)", r.tp, r.advantage()));
            }
            if r.std.finished.len() != r.seq.finished.len() {
                return Err(format!("tp={}: A/B served different request counts", r.tp));
            }
        }
        for w in rows.windows(2) {
            if w[1].advantage() < w[0].advantage() - 0.01 {
                return Err(format!(
                    "advantage shrank from tp={} ({:.3}x) to tp={} ({:.3}x)",
                    w[0].tp,
                    w[0].advantage(),
                    w[1].tp,
                    w[1].advantage()
                ));
            }
        }
    }
    let b1_tp8 = b1.last().expect("tp=8 row");
    if b1_tp8.advantage() < 1.05 {
        return Err(format!("tp=8 B=1 advantage too small: {:.3}x", b1_tp8.advantage()));
    }
    // Occupancy: sharding starves the standard policy; the sequence-aware
    // policy recovers a chunk of it at tp=8.
    let occ = |r: &FleetReport| r.mean_occupancy();
    if occ(&b1.last().unwrap().std) >= occ(&b1.first().unwrap().std) {
        return Err("standard occupancy should collapse as tp grows".into());
    }
    if occ(&b1_tp8.seq) <= occ(&b1_tp8.std) {
        return Err("sequence-aware should lift tp=8 occupancy".into());
    }
    // Router invariants at tp=8.
    let affinity = routers.iter().find(|r| r.router == "session-affinity").expect("ran");
    if affinity.affinity_violations() != 0 {
        return Err(format!("session-affinity violated {} sessions", affinity.affinity_violations()));
    }
    for r in routers {
        let lost = r.rejected + r.rejected_backpressure();
        if lost != 0 {
            return Err(format!("router '{}' run lost {lost} requests to rejection", r.router));
        }
    }
    Ok(())
}

fn occupancy_json(report: &FleetReport) -> Json {
    // Null = the replica ran no decode steps (not a measured 0%).
    Json::arr(
        report
            .replicas
            .iter()
            .map(|r| r.mean_occupancy.map(Json::num).unwrap_or(Json::Null)),
    )
}

fn sweep_json(rows: &[SweepRow]) -> Json {
    Json::arr(rows.iter().map(|r| {
        Json::obj(vec![
            ("tp_degree", Json::int(r.tp as i64)),
            ("shard_h_kv", Json::int(r.shard_h_kv as i64)),
            ("b1_tiles", Json::int(r.shard_h_kv as i64)),
            (
                "standard_tpot_mean_us",
                Json::num(SweepRow::tpot_mean(&r.std)),
            ),
            (
                "sequence_aware_tpot_mean_us",
                Json::num(SweepRow::tpot_mean(&r.seq)),
            ),
            ("tpot_speedup", Json::num(r.advantage())),
            ("standard_per_replica_occupancy", occupancy_json(&r.std)),
            ("sequence_aware_per_replica_occupancy", occupancy_json(&r.seq)),
            ("aggregate_tok_s_standard", Json::num(r.std.aggregate_tok_s)),
            ("aggregate_tok_s_sequence_aware", Json::num(r.seq.aggregate_tok_s)),
        ])
    }))
}

fn routers_json(routers: &[FleetReport]) -> Json {
    Json::arr(routers.iter().map(|r| {
        Json::obj(vec![
            ("router", Json::str(r.router)),
            ("imbalance", Json::num(r.imbalance())),
            ("affinity_violations", Json::int(r.affinity_violations() as i64)),
            ("aggregate_tok_s", Json::num(r.aggregate_tok_s)),
            (
                "ttft_p99_us",
                r.ttft.as_ref().map(|s| Json::num(s.p99)).unwrap_or(Json::Null),
            ),
            ("rejected", Json::int(r.rejected as i64)),
            ("rejected_backpressure", Json::int(r.rejected_backpressure() as i64)),
        ])
    }))
}

fn print_sweep(title: &str, rows: &[SweepRow]) {
    println!("\n== {title} ==");
    let mut t = Table::new(&[
        "tp",
        "H_KV/shard",
        "Std TPOT",
        "Seq TPOT",
        "Advantage",
        "Std occ",
        "Seq occ",
    ])
    .align(&[
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for r in rows {
        t.row(&[
            r.tp.to_string(),
            r.shard_h_kv.to_string(),
            us(SweepRow::tpot_mean(&r.std)),
            us(SweepRow::tpot_mean(&r.seq)),
            speedup(r.advantage()),
            format!("{:.1}%", r.std.mean_occupancy() * 100.0),
            format!("{:.1}%", r.seq.mean_occupancy() * 100.0),
        ]);
    }
    t.print();
}

fn main() {
    let json_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter().position(|a| a == "--json").and_then(|i| args.get(i + 1).cloned())
    };

    println!("== Cluster scale: TP sweep over the 8-KV-head model (2x H100 fleet) ==");
    let b1 = sweep(1, 16, 0xC1);
    print_sweep("B=1 (paper regime; per-shard tiles = 8/tp)", &b1);
    let batched = sweep(4, 24, 0xC2);
    print_sweep("max_batch=4 (tiles = B x 8/tp; window depends on live batch)", &batched);

    println!("\n== Routers at tp=8, sequence-aware, 4 replicas, Poisson multi-turn ==");
    let routers = router_comparison();
    let mut t = Table::new(&["Router", "Imbalance", "Affinity viol.", "TTFT p99", "tok/s"])
        .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for r in &routers {
        t.row(&[
            r.router.to_string(),
            format!("{:.3}", r.imbalance()),
            r.affinity_violations().to_string(),
            us(r.ttft.as_ref().map(|s| s.p99).unwrap_or(0.0)),
            format!("{:.0}", r.aggregate_tok_s),
        ]);
    }
    t.print();

    let verdict = verify(&b1, &batched, &routers);
    if let Some(path) = &json_path {
        let report = Json::obj(vec![
            ("bench", Json::str("cluster_scale")),
            (
                "regenerate_with",
                Json::str("cargo bench --bench cluster_scale -- --json BENCH_cluster_scale.json"),
            ),
            ("measured", Json::Bool(true)),
            (
                "model",
                Json::obj(vec![
                    ("h_q", Json::int(MODEL.h_q as i64)),
                    ("h_kv", Json::int(MODEL.h_kv as i64)),
                    ("d", Json::int(MODEL.d as i64)),
                ]),
            ),
            ("replicas_per_sweep_point", Json::int(REPLICAS as i64)),
            ("tp_sweep_b1", sweep_json(&b1)),
            ("tp_sweep_batched", sweep_json(&batched)),
            ("router_comparison", routers_json(&routers)),
            ("passed", Json::Bool(verdict.is_ok())),
        ]);
        std::fs::write(path, report.to_string_pretty()).expect("write json report");
        println!("\nwrote {path}");
    }
    match verdict {
        Ok(()) => println!("\nOK: advantage widens with tp, routers uphold their invariants"),
        Err(msg) => {
            eprintln!("\nFAILED: {msg}");
            std::process::exit(1);
        }
    }
}
