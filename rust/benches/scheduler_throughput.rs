//! Bench: coordinator throughput — simulator evals/s (the EA's budget),
//! engine step rate in simulated mode, block-manager ops, and the batcher
//! plan. L3 must never be the bottleneck (DESIGN.md §Perf: the simulator
//! needs >= 1M kernel evals/s for the evolutionary search).
//!
//! Run: `cargo bench --bench scheduler_throughput`

use fa3_split::backend::SimBackend;
use fa3_split::bench_harness::Bencher;
use fa3_split::coordinator::scheduler::{AttnGeometry, DecodeScheduler};
use fa3_split::coordinator::{BlockManager, BlockManagerConfig, Engine, Request};
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::planner::Planner;
use fa3_split::sim::Simulator;

fn main() {
    println!("== Coordinator / simulator throughput ==\n");
    let b = Bencher { warmup_iters: 500, samples: 50, batch_iters: 2_000 };

    // 1. Simulator kernel eval (the EA fitness inner loop).
    let sim = Simulator::h100();
    let md = Planner::standard()
        .plan_forced(&DecodeShape::llama70b_tp8(1, 512), 3)
        .metadata;
    let r_sim = b.run("sim.kernel_us        (one launch eval)", || sim.kernel_us(&md));
    let evals_per_s = 1e9 / r_sim.mean_ns();

    // 1b. The scheduler's batched per-step decision (planner-cached).
    let geometry_for_batch = AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 8192 };
    let mut sched =
        DecodeScheduler::new(Planner::sequence_aware(), geometry_for_batch, vec![1, 3]);
    let buckets = [(1usize, 512usize), (2, 512), (4, 1024), (8, 2048)];
    b.run("scheduler.decide_batch (4 buckets, cached)", || {
        sched.decide_batch(&buckets).unwrap()
    });
    // 1c. Same decision through the buffer-reusing entry point (the
    // per-step caller shape: zero output allocation after warmup).
    let mut decisions_scratch = Vec::new();
    b.run("scheduler.decide_batch_into (reused buffer)", || {
        sched.decide_batch_into(&mut decisions_scratch, &buckets).unwrap();
        decisions_scratch.len()
    });

    // 2. Block manager admit/release cycle (disjoint prompts: the
    //    hash-chain walk runs and misses, the pre-sharing worst case).
    let mut mgr = BlockManager::new(BlockManagerConfig::default());
    let mut id = 0u64;
    let mut prompt = vec![0i32; 200];
    b.run("block_manager        (admit+release)", || {
        id += 1;
        prompt[0] = id as i32; // unique content: no sharing
        mgr.admit(id, &prompt, 64).unwrap();
        mgr.release(id).unwrap();
    });
    // 2b. The same cycle when every prompt shares one hot prefix.
    let mut mgr_shared = BlockManager::new(BlockManagerConfig::default());
    let shared_prompt = vec![7i32; 200];
    mgr_shared.admit(0, &shared_prompt, 64).unwrap();
    let mut sid = 0u64;
    b.run("block_manager        (admit+release, shared prefix)", || {
        sid += 1;
        mgr_shared.admit(sid, &shared_prompt, 64).unwrap();
        mgr_shared.release(sid).unwrap();
    });

    // 3. Simulated engine: full serving steps (admit→schedule→decode→
    //    sample→retire) per second.
    let geometry = AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 };
    let heavy = Bencher { warmup_iters: 1, samples: 15, batch_iters: 1 };
    let r_engine = heavy.run("engine.run           (sim backend, 16 reqs x 32 tok)", || {
        let mut e = Engine::builder(Box::new(SimBackend::h100()))
            .planner(Planner::sequence_aware())
            .geometry(geometry)
            .available_splits(vec![1, 3])
            .build()
            .unwrap();
        for i in 0..16u64 {
            e.submit(Request::new(i, vec![1; 100], 32)).unwrap();
        }
        e.run_until_idle().unwrap().len()
    });
    // 16 requests x 32 tokens but batched 4-wide: ~128 decode steps/run.
    let steps_per_s = 128.0 * 1e9 / r_engine.mean_ns();

    println!();
    println!(
        "simulator: {:.2}M kernel evals/s (target >= 1M: {})",
        evals_per_s / 1e6,
        if evals_per_s >= 1e6 { "OK" } else { "MISS" }
    );
    println!("engine (sim backend): ~{steps_per_s:.0} full serving steps/s");
    if evals_per_s < 1e6 {
        std::process::exit(1);
    }
}
