//! Bench: ablation studies over the design choices (DESIGN.md §Perf /
//! experiment index): hardware scale, boundary sweep, pack_gqa layout,
//! sm_margin, and the policy ladder from conservative patch to learned
//! table to evolved genome.
//!
//! Run: `cargo bench --bench ablations`

use fa3_split::bench_harness::ablations;
use fa3_split::sim::Simulator;

fn main() {
    let sim = Simulator::h100();

    println!("== A1: hardware scale (same boundary cell across GPUs, §2.2) ==");
    ablations::hardware_scale().print();

    println!("\n== A2: boundary sweep (§4.1 — where behavior changes) ==");
    ablations::boundary_sweep(&sim).print();

    println!("\n== A3: pack_gqa layout ablation (§3.1 knob) ==");
    ablations::pack_gqa_ablation(&sim).print();

    println!("\n== A4: sm_margin ablation at the boundary shape (§3.1 knob) ==");
    ablations::sm_margin_ablation(&sim).print();

    println!("\n== A5: policy ladder (§4.1/§5.2 future work realized) ==");
    ablations::policy_ladder(&sim).print();

    println!("\nOK");
}
