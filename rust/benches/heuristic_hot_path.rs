//! Bench: the L3 hot path itself — the per-decode-step split planning.
//!
//! Before the planner façade, every decode step re-ran the policy and
//! rebuilt scheduler metadata from scratch (`policy.num_splits(..)` +
//! metadata construction); for long contexts that decision is the
//! efficiency loop. The planner's shape-bucket LRU memoizes it. This
//! bench measures both sides (the cursor layer above the LRU has its own
//! bench, `decode_hot_path`):
//!
//! * `uncached` rows run the planner with the cache disabled — the exact
//!   per-call work the seed's `SplitPolicy::metadata` did (decision +
//!   metadata build), plus plan derivation,
//! * `cached` rows run the default planner; the decode-loop scenario
//!   replays a growing-context generation, the serving access pattern the
//!   cache is designed for.
//!
//! Acceptance: cached planning must be no slower than the seed-equivalent
//! uncached construction on the loop scenarios (target: faster), and the
//! guard-path decision must stay under 100 ns (DESIGN.md §Perf).
//!
//! Run: `cargo bench --bench heuristic_hot_path [-- --json PATH]`
//! `--json` writes the machine-readable report (the committed
//! `BENCH_planner_hot_path.json` is regenerated this way).

use fa3_split::bench_harness::{Bencher, BenchResult};
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::heuristics::{SequenceAwarePolicy, SplitPolicy, StandardPolicy};
use fa3_split::planner::{DeviceProfile, Planner, PlannerBuilder};
use fa3_split::util::json::Json;

fn result_json(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("name", Json::str(r.name.clone())),
        ("mean_ns", Json::num(r.per_iter_ns.mean)),
        ("p50_ns", Json::num(r.per_iter_ns.p50)),
        ("p99_ns", Json::num(r.per_iter_ns.p99)),
        ("samples", Json::int(r.samples as i64)),
        ("iters_per_sample", Json::int(r.iters_per_sample as i64)),
    ])
}

fn main() {
    let json_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter()
            .position(|a| a == "--json")
            .and_then(|i| args.get(i + 1).cloned())
    };

    println!("== Planner hot path (per-decode-step planning cost) ==\n");
    let b = Bencher { warmup_iters: 1_000, samples: 60, batch_iters: 10_000 };

    let boundary = DecodeShape::llama70b_tp8(1, 512);
    let long = DecodeShape::llama70b_tp8(1, 4096);
    let dense = DecodeShape::decode(8, 2048, 64, 8, 128);
    let h100_sms = DeviceProfile::H100_SXM.num_sms;

    let mut results: Vec<BenchResult> = Vec::new();
    let mut record = |r: BenchResult| results.push(r);

    // Raw policy decisions (reference: the cheapest the seed's hot path
    // could ever be, before metadata construction).
    record(b.run("policy.num_splits raw  (L_K=512 guard path)", || {
        SequenceAwarePolicy.num_splits(&boundary, h100_sms, true)
    }));
    record(b.run("policy.num_splits raw  (L_K=4096 efficiency loop)", || {
        StandardPolicy.num_splits(&long, h100_sms, true)
    }));

    // Seed-equivalent per-call construction: planner with the cache off.
    let mut uncached_pat = PlannerBuilder::policy(SequenceAwarePolicy).cache_capacity(0).build();
    let mut uncached_std = PlannerBuilder::policy(StandardPolicy).cache_capacity(0).build();
    let r_unc_boundary =
        b.run("plan uncached          (L_K=512 guard path)", || uncached_pat.plan(&boundary));
    let r_unc_long =
        b.run("plan uncached          (L_K=4096 efficiency loop)", || uncached_std.plan(&long));
    let r_unc_dense =
        b.run("plan uncached          (dense B=8 H_KV=8)", || uncached_pat.plan(&dense));

    // Cached planner: steady-state hits.
    let mut cached_pat = Planner::sequence_aware();
    let mut cached_std = Planner::standard();
    let r_cache_boundary =
        b.run("plan cached            (L_K=512 guard path)", || cached_pat.plan(&boundary));
    let r_cache_long =
        b.run("plan cached            (L_K=4096 efficiency loop)", || cached_std.plan(&long));
    let r_cache_dense =
        b.run("plan cached            (dense B=8 H_KV=8)", || cached_pat.plan(&dense));

    // Decode-loop replay: L_K grows one token per call across the
    // 385..=512 boundary bucket — the serving access pattern.
    let mut loop_uncached =
        PlannerBuilder::policy(SequenceAwarePolicy).cache_capacity(0).build();
    let mut step_u = 0usize;
    let r_loop_uncached = b.run("decode loop uncached   (L_K 385..512 growing)", || {
        step_u += 1;
        loop_uncached.plan(&DecodeShape::llama70b_tp8(1, 385 + (step_u & 127)))
    });
    let mut loop_cached = Planner::sequence_aware();
    let mut step_c = 0usize;
    let r_loop_cached = b.run("decode loop cached     (L_K 385..512 growing)", || {
        step_c += 1;
        loop_cached.plan(&DecodeShape::llama70b_tp8(1, 385 + (step_c & 127)))
    });

    // Batched planning over a mixed decode step.
    let batch_shapes: Vec<DecodeShape> = [(1usize, 512usize), (2, 512), (4, 1024), (8, 2048)]
        .iter()
        .map(|&(batch, l_k)| DecodeShape::decode(batch, l_k, 8, 1, 128))
        .collect();
    let mut batch_planner = Planner::sequence_aware();
    let r_batch = b.run("plan_batch cached      (4 buckets per step)", || {
        batch_planner.plan_batch(&batch_shapes)
    });

    let loop_stats = loop_cached.cache_stats();
    println!("\ndecode-loop cache: {loop_stats:?}");

    let mut ok = true;
    let guard_ns = r_cache_boundary.mean_ns();
    println!(
        "guard-path cached plan: {guard_ns:.1} ns (target < 100 ns: {})",
        if guard_ns < 100.0 { "OK" } else { "MISS" }
    );
    ok &= guard_ns < 100.0;

    // The acceptance comparison: cached planning vs the seed's per-call
    // construction on the scenarios the serving loop actually runs.
    for (name, cached, uncached) in [
        ("efficiency loop", &r_cache_long, &r_unc_long),
        ("decode loop", &r_loop_cached, &r_loop_uncached),
    ] {
        let c = cached.mean_ns();
        let u = uncached.mean_ns();
        let verdict = if c <= u * 1.05 { "OK" } else { "MISS" };
        println!(
            "{name}: cached {c:.1} ns vs uncached {u:.1} ns ({:.2}x) — {verdict}",
            u / c
        );
        ok &= c <= u * 1.05;
    }

    for r in [
        &r_unc_boundary, &r_unc_long, &r_unc_dense, &r_cache_boundary, &r_cache_long,
        &r_cache_dense, &r_loop_uncached, &r_loop_cached, &r_batch,
    ] {
        record(r.clone());
    }

    if let Some(path) = json_path {
        let report = Json::obj(vec![
            ("bench", Json::str("heuristic_hot_path")),
            ("generated_by", Json::str("cargo bench --bench heuristic_hot_path -- --json <path>")),
            ("measured", Json::Bool(true)),
            ("rows", Json::arr(results.iter().map(result_json))),
            (
                "cache_effect",
                Json::obj(vec![
                    ("uncached_efficiency_loop_ns", Json::num(r_unc_long.mean_ns())),
                    ("cached_efficiency_loop_ns", Json::num(r_cache_long.mean_ns())),
                    ("uncached_decode_loop_ns", Json::num(r_loop_uncached.mean_ns())),
                    ("cached_decode_loop_ns", Json::num(r_loop_cached.mean_ns())),
                    (
                        "decode_loop_speedup",
                        Json::num(r_loop_uncached.mean_ns() / r_loop_cached.mean_ns().max(1e-9)),
                    ),
                    ("decode_loop_cache_hits", Json::int(loop_stats.hits as i64)),
                    ("decode_loop_cache_misses", Json::int(loop_stats.misses as i64)),
                ]),
            ),
            ("passed", Json::Bool(ok)),
        ]);
        match std::fs::write(&path, report.to_string_pretty()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }

    if !ok {
        std::process::exit(1);
    }
}
