//! Bench: the L3 hot path itself — the split decision and scheduler-
//! metadata construction that run on every decode step. The paper's patch
//! must not make dispatch slower: both policies should decide in
//! nanoseconds (DESIGN.md §Perf target: < 100 ns).
//!
//! Run: `cargo bench --bench heuristic_hot_path`

use fa3_split::bench_harness::Bencher;
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::heuristics::{SequenceAwarePolicy, SplitPolicy, StandardPolicy, H100_NUM_SMS};

fn main() {
    println!("== Heuristic hot path (per-launch decision cost) ==\n");
    let b = Bencher { warmup_iters: 1_000, samples: 60, batch_iters: 10_000 };

    let boundary = DecodeShape::llama70b_tp8(1, 512);
    let long = DecodeShape::llama70b_tp8(1, 4096);
    let dense = DecodeShape::decode(8, 2048, 64, 8, 128);

    let r1 = b.run("standard.num_splits  (L_K=512 guard path)", || {
        StandardPolicy.num_splits(&boundary, H100_NUM_SMS, true)
    });
    let r2 = b.run("patched.num_splits   (L_K=512 override path)", || {
        SequenceAwarePolicy.num_splits(&boundary, H100_NUM_SMS, true)
    });
    let r3 = b.run("standard.num_splits  (L_K=4096 efficiency loop)", || {
        StandardPolicy.num_splits(&long, H100_NUM_SMS, true)
    });
    b.run("patched.num_splits   (L_K=4096 efficiency loop)", || {
        SequenceAwarePolicy.num_splits(&long, H100_NUM_SMS, true)
    });
    b.run("patched.num_splits   (dense B=8 H_KV=8)", || {
        SequenceAwarePolicy.num_splits(&dense, H100_NUM_SMS, true)
    });
    b.run("patched.metadata     (full metadata build)", || {
        SequenceAwarePolicy.metadata(&boundary, 0, true)
    });

    println!();
    let guard_paths_ok = r1.mean_ns() < 100.0 && r2.mean_ns() < 100.0;
    println!(
        "guard-path decisions: standard {:.1} ns, patched {:.1} ns (target < 100 ns: {})",
        r1.mean_ns(),
        r2.mean_ns(),
        if guard_paths_ok { "OK" } else { "MISS" }
    );
    println!(
        "efficiency-loop decision: {:.1} ns (allocating loop; amortized once per shape by the scheduler cache)",
        r3.mean_ns()
    );
    if !guard_paths_ok {
        std::process::exit(1);
    }
}
