//! Bench: overload survival — priority preemption + SLO goodput vs the
//! refusal-only engine, on the simulated H100's virtual clock.
//!
//! Scenarios:
//!
//! * **Disabled identity** — `preemption.enabled = false` must be inert:
//!   a default-config run vs a run with the preemption knobs explicitly
//!   set (but disabled) and `slo = None` must be byte-identical,
//!   including timings, wall clock, and step counts. The overload
//!   machinery may not perturb the engine it is bolted onto.
//! * **2x sustained overload** — `ChatWorkload::mixed_open_loop` (3/4
//!   short interactive + 1/4 long-prompt batch) arriving at roughly
//!   twice the service rate of a `max_batch = 4` engine. Refusal-only
//!   baseline: bounded admission, no preemption, no shedding (SLO
//!   accounting on, so goodput is measured on both sides). Survival
//!   run: priority preemption on (`ResumePolicy::Auto` picks swap vs
//!   recompute per victim from the modeled costs) plus hopeless-shed.
//! * **Resume integrity** — every request the survival run preempted
//!   and later finished naturally is re-run alone in an uncontended
//!   engine; the token streams must match byte-for-byte (preemption
//!   moves *when* tokens are computed, never what gets computed).
//!
//! Gates (exit nonzero on failure — the CI `overload-survival` job):
//!
//! 1. the disabled-identity leg holds exactly,
//! 2. goodput (SLO-met tokens) with preemption strictly exceeds the
//!    refusal-only baseline,
//! 3. interactive-class p99 TTFT under preemption strictly beats the
//!    refusal-only baseline,
//! 4. at least one request was preempted and every preempted-then-
//!    resumed stream is identical to its uncontended run.
//!
//! Run: `cargo bench --bench overload_survival [-- --json PATH]`
//! (`BENCH_overload_survival.json` is regenerated this way.)

use std::collections::BTreeSet;

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{
    BatcherConfig, Engine, EngineConfig, FinishedRequest, PreemptionConfig, Priority,
    ResumePolicy, SloConfig, SubmitOptions,
};
use fa3_split::obs::EventKind;
use fa3_split::planner::Planner;
use fa3_split::util::json::Json;
use fa3_split::util::stats;
use fa3_split::workload::{ChatWorkload, GeneratedRequest};

const MAX_BATCH: usize = 4;
const N_REQUESTS: usize = 64;
/// Mean merged inter-arrival gap. A `max_batch = 4` engine drains the
/// mixed trace at roughly one request per ~200 µs; arrivals every
/// ~100 µs sustain ~2x overload for the whole stream.
const MEAN_GAP_US: u64 = 100;
const TRACE_CAPACITY: usize = 65_536;

fn engine(cfg: EngineConfig) -> Engine {
    Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(cfg)
        .build()
        .unwrap()
}

fn base_config() -> EngineConfig {
    EngineConfig {
        batcher: BatcherConfig::for_max_batch(MAX_BATCH),
        ..Default::default()
    }
}

fn overload_trace() -> Vec<GeneratedRequest> {
    ChatWorkload::mixed_open_loop(0x0B5E_55ED, N_REQUESTS, MEAN_GAP_US)
}

struct RunResult {
    done: Vec<FinishedRequest>,
    goodput_tokens: usize,
    goodput_tok_s: f64,
    preemptions: usize,
    shed: usize,
    wall_us: u64,
    steps: usize,
    preempted_ids: BTreeSet<u64>,
}

fn run_overload(cfg: EngineConfig) -> RunResult {
    let mut e = engine(cfg);
    for g in overload_trace() {
        if let Err(err) = e.submit_at_with(
            g.request,
            g.arrival_offset_us,
            SubmitOptions::default().priority(g.priority),
        ) {
            // Refusal is part of the scenario under overload.
            eprintln!("refused at submit: {err}");
        }
    }
    let done = e.run_until_idle().unwrap();
    let preempted_ids: BTreeSet<u64> = e
        .recorder()
        .events()
        .filter_map(|ev| match ev.kind {
            EventKind::Preempt { request, .. } => Some(request),
            _ => None,
        })
        .collect();
    RunResult {
        done,
        goodput_tokens: e.metrics.goodput_tokens,
        goodput_tok_s: e.metrics.goodput_tok_s(),
        preemptions: e.metrics.preemptions,
        shed: e.metrics.requests_shed,
        wall_us: e.metrics.wall_us,
        steps: e.metrics.steps,
        preempted_ids,
    }
}

fn byte_identical(a: &[FinishedRequest], b: &[FinishedRequest]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.id == y.id
                && x.tokens == y.tokens
                && x.reason == y.reason
                && x.timing.arrival_us == y.timing.arrival_us
                && x.timing.scheduled_us == y.timing.scheduled_us
                && x.timing.first_token_us == y.timing.first_token_us
                && x.timing.finished_us == y.timing.finished_us
        })
}

/// p99 TTFT over naturally-finished requests of one class (shed or
/// cancelled requests never produced a first token).
fn p99_ttft(done: &[FinishedRequest], class: Priority) -> Option<f64> {
    let ttfts: Vec<f64> = done
        .iter()
        .filter(|f| f.priority == class && f.reason.is_natural())
        .map(|f| f.timing.ttft_us() as f64)
        .collect();
    if ttfts.is_empty() {
        return None;
    }
    Some(stats::mean_p99(&ttfts).1)
}

fn main() {
    let json_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter().position(|a| a == "--json").and_then(|i| args.get(i + 1).cloned())
    };

    println!("== Overload survival: preemption + goodput vs refusal-only ==\n");

    // ------------------------------------------------------------------
    // Scenario 1: disabled identity.
    // ------------------------------------------------------------------
    let plain = run_overload(base_config());
    let knobs_off = run_overload(EngineConfig {
        // Every preemption knob moved off its default — but disabled.
        preemption: PreemptionConfig {
            enabled: false,
            max_per_step: 4,
            resume: ResumePolicy::Recompute,
            ..Default::default()
        },
        slo: None,
        ..base_config()
    });
    let mut plain_sorted = plain.done.clone();
    let mut knobs_sorted = knobs_off.done.clone();
    plain_sorted.sort_by_key(|f| f.id);
    knobs_sorted.sort_by_key(|f| f.id);
    let id_ok = byte_identical(&plain_sorted, &knobs_sorted)
        && plain.wall_us == knobs_off.wall_us
        && plain.steps == knobs_off.steps
        && knobs_off.preemptions == 0;
    println!(
        "disabled identity: default vs explicit-but-disabled knobs — {}",
        if id_ok { "byte-identical" } else { "DIVERGED" }
    );

    // ------------------------------------------------------------------
    // Scenario 2: 2x sustained overload, refusal-only vs survival.
    // ------------------------------------------------------------------
    // Refusal-only: measure goodput but change nothing — no preemption,
    // no shedding. This is the pre-PR engine with a measuring stick.
    let refusal = run_overload(EngineConfig {
        slo: Some(SloConfig { shed_hopeless: false, ..Default::default() }),
        ..base_config()
    });
    // Survival: preemption + auto resume + hopeless-shed.
    let survival = run_overload(EngineConfig {
        preemption: PreemptionConfig { enabled: true, ..Default::default() },
        slo: Some(SloConfig::default()),
        trace_capacity: TRACE_CAPACITY,
        ..base_config()
    });
    assert!(survival.preemptions > 0, "2x overload must trigger preemption");

    println!(
        "\noverload: {N_REQUESTS} requests, mean gap {MEAN_GAP_US} µs, max batch {MAX_BATCH}"
    );
    println!(
        "refusal-only: goodput {} tok ({:.0} tok/s), finished {}",
        refusal.goodput_tokens,
        refusal.goodput_tok_s,
        refusal.done.iter().filter(|f| f.reason.is_natural()).count()
    );
    println!(
        "survival:     goodput {} tok ({:.0} tok/s), finished {}, preemptions {}, shed {}",
        survival.goodput_tokens,
        survival.goodput_tok_s,
        survival.done.iter().filter(|f| f.reason.is_natural()).count(),
        survival.preemptions,
        survival.shed
    );
    let refusal_int_p99 = p99_ttft(&refusal.done, Priority::Interactive).unwrap();
    let survival_int_p99 = p99_ttft(&survival.done, Priority::Interactive).unwrap();
    println!(
        "interactive p99 TTFT: survival {survival_int_p99:.0} µs vs refusal-only \
         {refusal_int_p99:.0} µs"
    );

    // ------------------------------------------------------------------
    // Scenario 3: resume integrity against uncontended re-runs.
    // ------------------------------------------------------------------
    let trace = overload_trace();
    let mut resumed_checked = 0usize;
    let mut streams_identical = true;
    for f in &survival.done {
        if !survival.preempted_ids.contains(&f.id) || !f.reason.is_natural() {
            continue;
        }
        let g = trace.iter().find(|g| g.request.id == f.id).unwrap();
        let mut solo = engine(base_config());
        solo.submit(g.request.clone()).unwrap();
        let alone = solo.run_until_idle().unwrap();
        let same = alone.len() == 1
            && alone[0].tokens == f.tokens
            && alone[0].reason == f.reason;
        if !same {
            eprintln!("request {} diverged from its uncontended run", f.id);
        }
        streams_identical &= same;
        resumed_checked += 1;
    }
    println!(
        "resume integrity: {resumed_checked} preempted-then-finished streams checked \
         against uncontended runs"
    );

    // ------------------------------------------------------------------
    // Gates.
    // ------------------------------------------------------------------
    let mut ok = true;

    println!("\ndisabled preemption is byte-identical: {}", if id_ok { "OK" } else { "MISS" });
    ok &= id_ok;

    let g2 = survival.goodput_tokens > refusal.goodput_tokens;
    println!(
        "goodput beats refusal-only: {} vs {} tok ({})",
        survival.goodput_tokens,
        refusal.goodput_tokens,
        if g2 { "OK" } else { "MISS" }
    );
    ok &= g2;

    let g3 = survival_int_p99 < refusal_int_p99;
    println!(
        "interactive p99 TTFT beats refusal-only: {survival_int_p99:.0} µs vs \
         {refusal_int_p99:.0} µs ({})",
        if g3 { "OK" } else { "MISS" }
    );
    ok &= g3;

    let g4 = resumed_checked > 0 && streams_identical;
    println!(
        "resumed streams identical to uncontended ({resumed_checked} checked): {}",
        if g4 { "OK" } else { "MISS" }
    );
    ok &= g4;

    if let Some(path) = json_path {
        let report = Json::obj(vec![
            ("bench", Json::str("overload_survival")),
            (
                "generated_by",
                Json::str(
                    "cargo bench --bench overload_survival -- --json BENCH_overload_survival.json",
                ),
            ),
            ("measured", Json::Bool(true)),
            (
                "config",
                Json::obj(vec![
                    ("requests", Json::int(N_REQUESTS as i64)),
                    ("mean_gap_us", Json::int(MEAN_GAP_US as i64)),
                    ("max_batch", Json::int(MAX_BATCH as i64)),
                ]),
            ),
            ("disabled_identity", Json::Bool(id_ok)),
            (
                "overload",
                Json::obj(vec![
                    ("refusal_goodput_tokens", Json::int(refusal.goodput_tokens as i64)),
                    ("survival_goodput_tokens", Json::int(survival.goodput_tokens as i64)),
                    ("refusal_interactive_p99_ttft_us", Json::num(refusal_int_p99)),
                    ("survival_interactive_p99_ttft_us", Json::num(survival_int_p99)),
                    ("preemptions", Json::int(survival.preemptions as i64)),
                    ("shed", Json::int(survival.shed as i64)),
                ]),
            ),
            (
                "resume_integrity",
                Json::obj(vec![
                    ("streams_checked", Json::int(resumed_checked as i64)),
                    ("identical", Json::Bool(streams_identical)),
                ]),
            ),
            ("passed", Json::Bool(ok)),
        ]);
        match std::fs::write(&path, report.to_string_pretty()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }

    if !ok {
        std::process::exit(1);
    }
}
