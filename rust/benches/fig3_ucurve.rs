//! Bench: regenerate Figure 3 (extended split sweep s = 1..64 for
//! Batch = 1, L_K = 512, H_KV = 1, D = 128, precomputed metadata).
//!
//! Run: `cargo bench --bench fig3_ucurve`

use fa3_split::bench_harness::ucurve;
use fa3_split::sim::Simulator;

fn main() {
    let sim = Simulator::h100();
    println!("== Figure 3: split sweep, B=1 L_K=512 H_KV=1 D=128 (simulated H100) ==\n");
    let points = ucurve::run(&sim, 301, 0xF163);
    print!("{}", ucurve::render_table(&points));
    println!();
    println!("{}", ucurve::render_plot(&points, 14));
    let best = points
        .iter()
        .cloned()
        .reduce(|a, b| if b.latency_us < a.latency_us { b } else { a })
        .unwrap();
    let p1 = points[0];
    let p3 = points.iter().find(|p| p.num_splits == 3).unwrap();
    println!(
        "s=1: {:.2}µs | s=3 (paper's choice): {:.2}µs | best: s={} at {:.2}µs (s=3 within {:.1}% of best)",
        p1.latency_us,
        p3.latency_us,
        best.num_splits,
        best.latency_us,
        (p3.latency_us - best.latency_us) / best.latency_us * 100.0
    );
    match ucurve::verify(&points) {
        Ok(()) => println!("OK: steep drop from s=1, shallow plateau, s=3 inside it"),
        Err(e) => {
            eprintln!("FIGURE 3 SHAPE VIOLATION: {e}");
            std::process::exit(1);
        }
    }
}
