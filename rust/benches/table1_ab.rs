//! Bench: regenerate Table 1 (standard vs sequence-aware patched kernel,
//! Batch = 1, H_KV ∈ {1,2,8}, BF16, precomputed scheduler metadata) plus
//! the §5.1 no-metadata contrast column.
//!
//! Run: `cargo bench --bench table1_ab`

use fa3_split::bench_harness::table1;
use fa3_split::sim::Simulator;

fn main() {
    let sim = Simulator::h100();
    println!("== Table 1: kernel A/B, Batch = 1 (simulated H100, 501 interleaved replays) ==\n");
    let cells = table1::run(&sim, 501, 0xAB01);
    print!("{}", table1::render(&cells));
    println!();
    match table1::verify(&cells) {
        Ok(()) => {
            let targets: Vec<f64> = cells
                .iter()
                .filter(|c| c.row.l_k == 512 && c.row.h_kv <= 2)
                .map(|c| c.speedup())
                .collect();
            println!(
                "OK: wins only at the L_K=512 low-tile cells ({}), all controls 1.00x",
                targets.iter().map(|s| format!("{s:.2}x")).collect::<Vec<_>>().join(", ")
            );
        }
        Err(e) => {
            eprintln!("TABLE 1 SHAPE VIOLATION: {e}");
            std::process::exit(1);
        }
    }
}
