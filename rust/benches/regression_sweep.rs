//! Bench: regenerate §5.3's 160-configuration regression sweep
//! (Batch ∈ {1,2,4,8} × L_K ∈ {128..8192} × H_KV ∈ {1,2,4,8,32}).
//!
//! Run: `cargo bench --bench regression_sweep`

use fa3_split::bench_harness::regression;
use fa3_split::sim::Simulator;

fn main() {
    let sim = Simulator::h100();
    println!("== §5.3: 160-config safety/regression sweep (simulated H100) ==\n");
    let cells = regression::run(&sim, 201, 0x5E53);
    print!("{}", regression::render(&cells));
    match regression::verify(&cells) {
        Ok(()) => println!("OK: >= 0.99x everywhere; wins exactly at the low-tile L_K=512 cells"),
        Err(e) => {
            eprintln!("REGRESSION SWEEP VIOLATION: {e}");
            std::process::exit(1);
        }
    }
}
