//! Bench: the zero-allocation decode hot path — plan cursors vs the
//! hashed LRU vs uncached planning, plus steady-state engine step-loop
//! allocation counts under a counting global allocator.
//!
//! Three scenarios:
//!
//! * **Single bucket** — one growing decode trajectory (`L_K` 385..512,
//!   the paper's boundary bucket). Here the LRU's one-entry fast path
//!   already avoids hashing, so the cursor's job is only to be no slower.
//! * **Interleaved buckets** — two live decode-batch sizes alternating
//!   per call, the steady state of any engine serving mixed batches (and
//!   of a fleet stepping many replicas per virtual tick): the LRU's
//!   one-entry fast path thrashes and every plan pays the full
//!   hash + map lookup, while the cursor side holds one cursor per bucket
//!   (exactly what `DecodeScheduler` does). **The acceptance gate: the
//!   cursor path must deliver ≥ 5x the hashed-LRU path's plans/sec.**
//! * **Engine steps** — a warmed-up `SimBackend` engine decoding a steady
//!   batch; the counting allocator must observe **zero** heap
//!   acquisitions across the measured window (the same property
//!   `tests/alloc_guard.rs` enforces, reported here as a number).
//!
//! Run: `cargo bench --bench decode_hot_path [-- --json PATH]`
//! (`BENCH_decode_hot_path.json` is regenerated this way; the bench exits
//! nonzero if any gate fails, which is what the CI job checks.)

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::bench_harness::{BenchResult, Bencher};
use fa3_split::coordinator::{BlockManagerConfig, Engine, EngineConfig, Request};
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::planner::{PlanCursor, Planner, PlannerBuilder};
use fa3_split::util::alloc_counter::{self, CountingAllocator};
use fa3_split::util::json::Json;

#[global_allocator]
static COUNTER: CountingAllocator = CountingAllocator;

fn result_json(r: &BenchResult) -> Json {
    let plans_per_sec = if r.per_iter_ns.mean > 0.0 { 1e9 / r.per_iter_ns.mean } else { 0.0 };
    Json::obj(vec![
        ("name", Json::str(r.name.clone())),
        ("mean_ns", Json::num(r.per_iter_ns.mean)),
        ("p50_ns", Json::num(r.per_iter_ns.p50)),
        ("p99_ns", Json::num(r.per_iter_ns.p99)),
        ("plans_per_sec", Json::num(plans_per_sec)),
        ("samples", Json::int(r.samples as i64)),
        ("iters_per_sample", Json::int(r.iters_per_sample as i64)),
    ])
}

/// The interleaved sweep's shape for call `i`: two live decode buckets
/// (batch 1 and 2) alternating per call, `L_K` growing through the
/// boundary bucket. Shared by the LRU and cursor sides so they plan the
/// identical sequence.
fn interleaved_shape(i: usize) -> DecodeShape {
    let l_k = 385 + ((i >> 1) & 127);
    let batch = 1 + (i & 1);
    DecodeShape::llama70b_tp8(batch, l_k)
}

fn main() {
    let json_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter().position(|a| a == "--json").and_then(|i| args.get(i + 1).cloned())
    };

    println!("== Decode hot path (cursor vs LRU vs uncached, alloc counts) ==\n");
    let b = Bencher { warmup_iters: 1_000, samples: 60, batch_iters: 10_000 };
    let mut results: Vec<BenchResult> = Vec::new();

    // ------------------------------------------------------------------
    // Scenario 1: single growing bucket (LRU best case).
    // ------------------------------------------------------------------
    let mut p_unc = PlannerBuilder::policy(fa3_split::heuristics::SequenceAwarePolicy)
        .cache_capacity(0)
        .build();
    let mut step_u = 0usize;
    let r_unc_single = b.run("uncached  single bucket (L_K 385..512)", || {
        step_u += 1;
        p_unc.plan(&DecodeShape::llama70b_tp8(1, 385 + (step_u & 127)))
    });

    let mut p_lru = Planner::sequence_aware();
    let mut step_l = 0usize;
    let r_lru_single = b.run("LRU       single bucket (L_K 385..512)", || {
        step_l += 1;
        p_lru.plan(&DecodeShape::llama70b_tp8(1, 385 + (step_l & 127)))
    });

    let mut p_cur = Planner::sequence_aware();
    let mut cursor = p_cur.cursor();
    let mut step_c = 0usize;
    let r_cursor_single = b.run("cursor    single bucket (L_K 385..512)", || {
        step_c += 1;
        cursor.plan(&mut p_cur, &DecodeShape::llama70b_tp8(1, 385 + (step_c & 127)))
    });

    // ------------------------------------------------------------------
    // Scenario 2: two live buckets interleaved — THE steady-state sweep.
    // ------------------------------------------------------------------
    let mut p_lru2 = Planner::sequence_aware();
    let mut i_l = 0usize;
    let r_lru_inter = b.run("LRU       two buckets interleaved", || {
        i_l += 1;
        p_lru2.plan(&interleaved_shape(i_l))
    });

    let mut p_cur2 = Planner::sequence_aware();
    let mut cursors = [PlanCursor::new(), PlanCursor::new()];
    let mut i_c = 0usize;
    let r_cursor_inter = b.run("cursor    two buckets interleaved", || {
        i_c += 1;
        cursors[i_c & 1].plan(&mut p_cur2, &interleaved_shape(i_c))
    });

    let lru_stats = p_lru2.cache_stats();
    let cur_stats = {
        let mut s = cursors[0].stats();
        s.merge(cursors[1].stats());
        s
    };
    println!("\ninterleaved LRU cache: {lru_stats:?}");
    println!("interleaved cursors:   {cur_stats:?}");

    // ------------------------------------------------------------------
    // Scenario 3: steady-state engine step-loop allocations.
    // ------------------------------------------------------------------
    let mut cfg = EngineConfig::default();
    // Long generations so the measured window never retires a row; the
    // default 1024-token KV cap would refuse them as unschedulable.
    cfg.blocks = BlockManagerConfig { block_size: 16, num_blocks: 4096, max_seq: 8192, ..Default::default() };
    let mut engine = Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 8192 })
        .config(cfg)
        .build()
        .unwrap();
    for id in 0..2u64 {
        // Handles dropped: fire-and-forget streaming (the guard config).
        drop(engine.submit(Request::new(id, vec![1; 300], 4000)).unwrap());
    }
    for _ in 0..32 {
        engine.step().unwrap(); // warmup: prefill + scratch sizing
    }
    const MEASURED_STEPS: usize = 1500;
    engine.metrics.reserve_capacity(MEASURED_STEPS + 16, 16);
    let alloc_before = alloc_counter::total_allocations();
    for _ in 0..MEASURED_STEPS {
        engine.step().unwrap();
    }
    let allocs = alloc_counter::total_allocations() - alloc_before;
    let allocs_per_step = allocs as f64 / MEASURED_STEPS as f64;
    println!(
        "engine steady state: {allocs} heap acquisitions over {MEASURED_STEPS} steps \
         ({allocs_per_step:.4}/step), cursor {:?}",
        engine.cursor_stats()
    );

    // ------------------------------------------------------------------
    // Gates.
    // ------------------------------------------------------------------
    let mut ok = true;

    // Gate 1 (acceptance): cursor >= 5x hashed-LRU plans/sec on the
    // interleaved steady-state sweep.
    let speedup_inter = r_lru_inter.mean_ns() / r_cursor_inter.mean_ns().max(1e-9);
    let g1 = speedup_inter >= 5.0;
    println!(
        "\ncursor vs hashed LRU (interleaved): {:.1} ns vs {:.1} ns = {speedup_inter:.2}x \
         (target >= 5x: {})",
        r_cursor_inter.mean_ns(),
        r_lru_inter.mean_ns(),
        if g1 { "OK" } else { "MISS" }
    );
    ok &= g1;

    // Gate 2: no regression where the LRU was already at its best (the
    // one-entry fast path): cursor <= 1.10x single-bucket LRU.
    let g2 = r_cursor_single.mean_ns() <= r_lru_single.mean_ns() * 1.10;
    println!(
        "cursor vs LRU fast path (single bucket): {:.1} ns vs {:.1} ns ({})",
        r_cursor_single.mean_ns(),
        r_lru_single.mean_ns(),
        if g2 { "OK" } else { "MISS" }
    );
    ok &= g2;

    // Gate 3: the steady-state engine step is allocation-free.
    let g3 = allocs == 0;
    println!(
        "steady-state allocations/step: {allocs_per_step:.4} (target 0: {})",
        if g3 { "OK" } else { "MISS" }
    );
    ok &= g3;

    // Context row: uncached vs cursor (the full per-step recompute the
    // seed paid — orders of magnitude, reported not gated).
    let speedup_uncached = r_unc_single.mean_ns() / r_cursor_single.mean_ns().max(1e-9);
    println!("cursor vs uncached (single bucket): {speedup_uncached:.2}x");

    for r in [
        &r_unc_single,
        &r_lru_single,
        &r_cursor_single,
        &r_lru_inter,
        &r_cursor_inter,
    ] {
        results.push((*r).clone());
    }

    if let Some(path) = json_path {
        let report = Json::obj(vec![
            ("bench", Json::str("decode_hot_path")),
            (
                "generated_by",
                Json::str("cargo bench --bench decode_hot_path -- --json <path>"),
            ),
            ("measured", Json::Bool(true)),
            ("rows", Json::arr(results.iter().map(result_json))),
            (
                "cursor_effect",
                Json::obj(vec![
                    ("lru_interleaved_ns", Json::num(r_lru_inter.mean_ns())),
                    ("cursor_interleaved_ns", Json::num(r_cursor_inter.mean_ns())),
                    ("cursor_vs_lru_interleaved_speedup", Json::num(speedup_inter)),
                    ("cursor_vs_uncached_single_speedup", Json::num(speedup_uncached)),
                    ("interleaved_cursor_hits", Json::int(cur_stats.hits as i64)),
                    ("interleaved_cursor_refills", Json::int(cur_stats.refills as i64)),
                    ("interleaved_lru_hits", Json::int(lru_stats.hits as i64)),
                    ("interleaved_lru_misses", Json::int(lru_stats.misses as i64)),
                ]),
            ),
            (
                "steady_state_alloc",
                Json::obj(vec![
                    ("measured_steps", Json::int(MEASURED_STEPS as i64)),
                    ("heap_acquisitions", Json::int(allocs as i64)),
                    ("allocs_per_step", Json::num(allocs_per_step)),
                ]),
            ),
            ("passed", Json::Bool(ok)),
        ]);
        match std::fs::write(&path, report.to_string_pretty()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }

    if !ok {
        std::process::exit(1);
    }
}
