//! Bench: continuous batching with chunked prefill vs run-to-completion
//! static batching, on the simulated H100's virtual clock.
//!
//! Scenarios:
//!
//! * **Monolithic identity** — the step composer at chunk = ∞ must be
//!   byte-identical to the legacy engine: the default schedule vs an
//!   explicitly-constructed monolithic schedule (full identity including
//!   timings, wall clock, and step counts — the composed plan routes
//!   through the unchanged prefill/decode paths), and a Bounded chunk
//!   large enough to swallow any prompt vs monolithic (token-stream and
//!   finish-reason identity: chunking moves *when* prompt tokens are
//!   ingested, never what gets computed).
//! * **Mixed open-loop load** — `ChatWorkload::mixed_open_loop` (3/4
//!   short interactive + 1/4 long-prompt batch) at an arrival rate ~4x
//!   the service rate. Run-to-completion baseline: groups of `max_batch`
//!   requests, each group submitted (at its TRUE arrival times) only
//!   after the previous group fully drains — classic static batching.
//!   Continuous chunked: every request submitted at its arrival,
//!   per-step admission, 128-token chunks under a 512-token step budget.
//! * **Occupancy by row kind** — the chunked run's per-wave planned SM
//!   occupancy split into decode waves vs chunk waves (chunk waves pack
//!   `l_q` query rows per M-block, so their occupancy sits far above
//!   low-head-count decode).
//!
//! Gates (exit nonzero on failure — the CI `continuous-batching` job):
//!
//! 1. both identity legs hold exactly,
//! 2. chunked p99 TTFT under mixed load strictly below run-to-completion,
//! 3. chunked interactive-class p99 TTFT strictly below RTC's,
//! 4. chunked throughput >= 0.97x run-to-completion (latency is not
//!    bought with throughput),
//! 5. decode-wave and chunk-wave mean occupancies both in (0, 1].
//!
//! Run: `cargo bench --bench continuous_batching [-- --json PATH]`
//! (`BENCH_continuous_batching.json` is regenerated this way.)

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{
    BatcherConfig, Engine, EngineConfig, FinishedRequest, Priority, SubmitOptions,
};
use fa3_split::planner::Planner;
use fa3_split::schedule::{ChunkPolicy, ScheduleConfig, TokenBudget};
use fa3_split::util::json::Json;
use fa3_split::util::stats;
use fa3_split::workload::{ChatWorkload, GeneratedRequest};

const MAX_BATCH: usize = 8;
const CHUNK: usize = 128;
const STEP_BUDGET: usize = 512;
const N_REQUESTS: usize = 64;
const MEAN_GAP_US: u64 = 100;

fn engine(schedule: ScheduleConfig) -> Engine {
    Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(EngineConfig {
            batcher: BatcherConfig::for_max_batch(MAX_BATCH),
            schedule,
            ..Default::default()
        })
        .build()
        .unwrap()
}

// ----------------------------------------------------------------------
// Identity leg.
// ----------------------------------------------------------------------

fn identity_trace() -> Vec<GeneratedRequest> {
    ChatWorkload {
        seed: 0x1DE7,
        n_requests: 32,
        prompt_median: 160,
        output_mean: 24,
        output_cap: 48,
        mean_gap_us: 200,
        ..Default::default()
    }
    .generate()
}

fn run_identity(schedule: ScheduleConfig) -> (Vec<FinishedRequest>, u64, usize, usize) {
    let mut e = engine(schedule);
    for g in identity_trace() {
        e.submit_at(g.request, g.arrival_offset_us).expect("schedulable");
    }
    let mut done = e.run_until_idle().unwrap();
    done.sort_by_key(|f| f.id);
    (done, e.metrics.wall_us, e.metrics.steps, e.metrics.mixed_steps)
}

fn byte_identical(a: &[FinishedRequest], b: &[FinishedRequest]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.id == y.id
                && x.tokens == y.tokens
                && x.reason == y.reason
                && x.timing.arrival_us == y.timing.arrival_us
                && x.timing.scheduled_us == y.timing.scheduled_us
                && x.timing.first_token_us == y.timing.first_token_us
                && x.timing.finished_us == y.timing.finished_us
        })
}

fn token_identical(a: &[FinishedRequest], b: &[FinishedRequest]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.id == y.id && x.tokens == y.tokens && x.reason == y.reason)
}

// ----------------------------------------------------------------------
// Mixed open-loop load leg.
// ----------------------------------------------------------------------

struct LoadResult {
    done: Vec<FinishedRequest>,
    tok_s: f64,
    mean_occupancy: Option<f64>,
    mean_chunk_occupancy: Option<f64>,
    mixed_steps: usize,
}

fn mixed_trace() -> Vec<GeneratedRequest> {
    ChatWorkload::mixed_open_loop(0xC0117, N_REQUESTS, MEAN_GAP_US)
}

/// Continuous batching: every request enters at its true arrival time;
/// admission happens every step.
fn run_continuous(schedule: ScheduleConfig) -> LoadResult {
    let mut e = engine(schedule);
    for g in mixed_trace() {
        e.submit_at_with(
            g.request,
            g.arrival_offset_us,
            SubmitOptions::default().priority(g.priority),
        )
        .expect("schedulable");
    }
    let done = e.run_until_idle().unwrap();
    LoadResult {
        done,
        tok_s: e.metrics.throughput_tok_s(),
        mean_occupancy: e.metrics.mean_occupancy(),
        mean_chunk_occupancy: e.metrics.mean_chunk_occupancy(),
        mixed_steps: e.metrics.mixed_steps,
    }
}

/// Run-to-completion static batching: the same trace in arrival order,
/// but a group of `MAX_BATCH` requests must fully drain before the next
/// group is admitted. TTFT is still measured from each request's TRUE
/// arrival time (the timestamp passed to `submit_at_with`), so queueing
/// behind earlier groups is charged to the baseline — that queueing is
/// exactly what continuous batching removes.
fn run_rtc() -> LoadResult {
    let mut e = engine(ScheduleConfig::default());
    let trace = mixed_trace();
    let mut done = Vec::with_capacity(trace.len());
    for group in trace.chunks(MAX_BATCH) {
        for g in group {
            e.submit_at_with(
                g.request.clone(),
                g.arrival_offset_us,
                SubmitOptions::default().priority(g.priority),
            )
            .expect("schedulable");
        }
        done.extend(e.run_until_idle().unwrap());
    }
    LoadResult {
        done,
        tok_s: e.metrics.throughput_tok_s(),
        mean_occupancy: e.metrics.mean_occupancy(),
        mean_chunk_occupancy: e.metrics.mean_chunk_occupancy(),
        mixed_steps: e.metrics.mixed_steps,
    }
}

fn ttft_percentiles(done: &[FinishedRequest], class: Option<Priority>) -> Option<(f64, f64)> {
    let ttfts: Vec<f64> = done
        .iter()
        .filter(|f| class.map_or(true, |c| f.priority == c))
        .map(|f| f.timing.ttft_us() as f64)
        .collect();
    if ttfts.is_empty() {
        return None;
    }
    Some(stats::mean_p99(&ttfts))
}

fn main() {
    let json_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter().position(|a| a == "--json").and_then(|i| args.get(i + 1).cloned())
    };

    println!("== Continuous batching: chunked prefill vs run-to-completion ==\n");

    // ------------------------------------------------------------------
    // Scenario 1: monolithic identity.
    // ------------------------------------------------------------------
    let (dflt, dflt_wall, dflt_steps, dflt_mixed) = run_identity(ScheduleConfig::default());
    let (mono, mono_wall, mono_steps, mono_mixed) = run_identity(ScheduleConfig {
        chunk: ChunkPolicy::Monolithic,
        budget: TokenBudget::unbounded(),
    });
    let id_full = byte_identical(&dflt, &mono)
        && dflt_wall == mono_wall
        && dflt_steps == mono_steps
        && dflt_mixed == 0
        && mono_mixed == 0;
    // Chunk = ∞ as a *bounded* policy: every prompt fits one chunk, so
    // ingestion happens at the same steps — but the rows ride the mixed
    // path. Token streams and reasons must be unchanged.
    let (inf, _, _, inf_mixed) =
        run_identity(ScheduleConfig::bounded(1024, TokenBudget::unbounded()));
    let id_inf = token_identical(&dflt, &inf);
    println!(
        "monolithic identity: default vs explicit — {}; bounded(∞) token identity — {} \
         ({inf_mixed} mixed steps rode the composer)",
        if id_full { "byte-identical" } else { "DIVERGED" },
        if id_inf { "identical" } else { "DIVERGED" },
    );

    // ------------------------------------------------------------------
    // Scenario 2: mixed open-loop load at ~4x service rate.
    // ------------------------------------------------------------------
    let chunked = run_continuous(ScheduleConfig::bounded(
        CHUNK,
        TokenBudget::capped(STEP_BUDGET),
    ));
    let rtc = run_rtc();
    assert_eq!(chunked.done.len(), N_REQUESTS, "continuous run must finish the trace");
    assert_eq!(rtc.done.len(), N_REQUESTS, "RTC run must finish the trace");
    assert!(chunked.mixed_steps > 0, "the chunked run must actually interleave");
    assert_eq!(rtc.mixed_steps, 0, "the RTC baseline must stay monolithic");

    println!(
        "\nmixed load: {N_REQUESTS} requests, mean gap {MEAN_GAP_US} µs, \
         chunk {CHUNK}, step budget {STEP_BUDGET}, {} mixed steps",
        chunked.mixed_steps
    );
    println!("          class |      chunked TTFT µs |          RTC TTFT µs");
    let mut rows: Vec<(&str, Option<Priority>)> = vec![("all", None)];
    rows.extend(Priority::all().map(|c| (c.name(), Some(c))));
    for (label, class) in rows {
        let (c_mean, c_p99) = match ttft_percentiles(&chunked.done, class) {
            Some(x) => x,
            None => continue,
        };
        let (r_mean, r_p99) = ttft_percentiles(&rtc.done, class).unwrap();
        println!(
            "{label:>15} | mean {c_mean:>7.0} p99 {c_p99:>7.0} | mean {r_mean:>7.0} p99 {r_p99:>7.0}"
        );
    }
    let (_, chunked_p99) = ttft_percentiles(&chunked.done, None).unwrap();
    let (_, rtc_p99) = ttft_percentiles(&rtc.done, None).unwrap();
    let (_, chunked_int_p99) =
        ttft_percentiles(&chunked.done, Some(Priority::Interactive)).unwrap();
    let (_, rtc_int_p99) = ttft_percentiles(&rtc.done, Some(Priority::Interactive)).unwrap();
    println!(
        "throughput: chunked {:.0} tok/s vs RTC {:.0} tok/s",
        chunked.tok_s, rtc.tok_s
    );

    // ------------------------------------------------------------------
    // Scenario 3: occupancy by row kind (from the chunked run).
    // ------------------------------------------------------------------
    let decode_occ = chunked.mean_occupancy.unwrap_or(0.0);
    let chunk_occ = chunked.mean_chunk_occupancy.unwrap_or(0.0);
    println!(
        "occupancy by row kind: decode waves {:.1}%, chunk waves {:.1}%",
        decode_occ * 100.0,
        chunk_occ * 100.0
    );

    // ------------------------------------------------------------------
    // Gates.
    // ------------------------------------------------------------------
    let mut ok = true;

    println!("\nmonolithic identity (byte + chunk=∞ token): {}", if id_full && id_inf { "OK" } else { "MISS" });
    ok &= id_full && id_inf;

    let g2 = chunked_p99 < rtc_p99;
    println!(
        "chunked p99 TTFT below run-to-completion: {chunked_p99:.0} µs vs {rtc_p99:.0} µs ({})",
        if g2 { "OK" } else { "MISS" }
    );
    ok &= g2;

    let g3 = chunked_int_p99 < rtc_int_p99;
    println!(
        "interactive-class p99 TTFT: {chunked_int_p99:.0} µs vs {rtc_int_p99:.0} µs ({})",
        if g3 { "OK" } else { "MISS" }
    );
    ok &= g3;

    let g4 = chunked.tok_s >= 0.97 * rtc.tok_s;
    println!(
        "throughput held (>= 0.97x RTC): {:.0} vs {:.0} tok/s ({})",
        chunked.tok_s,
        rtc.tok_s,
        if g4 { "OK" } else { "MISS" }
    );
    ok &= g4;

    let g5 = decode_occ > 0.0 && decode_occ <= 1.0 && chunk_occ > 0.0 && chunk_occ <= 1.0;
    println!(
        "occupancy split sane (both row kinds in (0,1]): {}",
        if g5 { "OK" } else { "MISS" }
    );
    ok &= g5;

    if let Some(path) = json_path {
        let class_json = |r: &LoadResult| {
            Json::arr(Priority::all().iter().filter_map(|&c| {
                let (mean, p99) = ttft_percentiles(&r.done, Some(c))?;
                Some(Json::obj(vec![
                    ("class", Json::str(c.name())),
                    ("mean_ttft_us", Json::num(mean)),
                    ("p99_ttft_us", Json::num(p99)),
                ]))
            }))
        };
        let report = Json::obj(vec![
            ("bench", Json::str("continuous_batching")),
            (
                "generated_by",
                Json::str(
                    "cargo bench --bench continuous_batching -- --json BENCH_continuous_batching.json",
                ),
            ),
            ("measured", Json::Bool(true)),
            (
                "config",
                Json::obj(vec![
                    ("requests", Json::int(N_REQUESTS as i64)),
                    ("mean_gap_us", Json::int(MEAN_GAP_US as i64)),
                    ("chunk_tokens", Json::int(CHUNK as i64)),
                    ("max_batch_tokens", Json::int(STEP_BUDGET as i64)),
                    ("max_batch", Json::int(MAX_BATCH as i64)),
                ]),
            ),
            (
                "identity",
                Json::obj(vec![
                    ("default_vs_monolithic_byte", Json::Bool(id_full)),
                    ("bounded_inf_tokens", Json::Bool(id_inf)),
                ]),
            ),
            (
                "mixed_load",
                Json::obj(vec![
                    ("chunked_p99_ttft_us", Json::num(chunked_p99)),
                    ("rtc_p99_ttft_us", Json::num(rtc_p99)),
                    ("chunked_tok_s", Json::num(chunked.tok_s)),
                    ("rtc_tok_s", Json::num(rtc.tok_s)),
                    ("chunked_mixed_steps", Json::int(chunked.mixed_steps as i64)),
                    ("chunked_by_class", class_json(&chunked)),
                    ("rtc_by_class", class_json(&rtc)),
                ]),
            ),
            (
                "occupancy",
                Json::obj(vec![
                    ("decode_waves", Json::num(decode_occ)),
                    ("chunk_waves", Json::num(chunk_occ)),
                ]),
            ),
            ("passed", Json::Bool(ok)),
        ]);
        match std::fs::write(&path, report.to_string_pretty()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }

    if !ok {
        std::process::exit(1);
    }
}
