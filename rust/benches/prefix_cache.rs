//! Bench: the prefix-sharing paged KV cache — shared system-prompt
//! fan-outs vs matched disjoint controls at an equal KV budget, on the
//! simulated H100's virtual clock.
//!
//! Scenarios (the workload pair is an *exact* A/B: `prefix_fanout` is
//! the only knob that moves, so lengths, suffixes, and arrivals are
//! byte-identical across the sweep — see `ChatWorkload`):
//!
//! * **Fan-out sweep** — fanout ∈ {1, 2, 4, 8, 16} over a 256-token
//!   shared system prompt, tight block budget: TTFT, drain wall,
//!   admitted throughput, and prefix hit-rate per point.
//! * **Disjoint identity** — random (unsharable) traffic with sharing
//!   on vs off must produce byte-identical results and wall time: the
//!   sharing machinery is free when nothing is shared.
//! * **Steady-state allocations** — a warmed-up engine decoding a
//!   shared-prefix batch under the counting allocator: the PR-4
//!   zero-allocation decode guarantee must survive sharing (COW forks
//!   and probes live on the admission path, not the step loop).
//!
//! Gates (exit nonzero on failure — the CI `prefix-cache` job):
//!
//! 1. shared (fanout 8) mean TTFT < disjoint (fanout 1) mean TTFT,
//! 2. shared (fanout 8) admitted throughput > disjoint at equal budget,
//! 3. disjoint identity holds exactly (tokens, reasons, timings, wall),
//! 4. zero heap acquisitions per warmed-up decode step with sharing on.
//!
//! Run: `cargo bench --bench prefix_cache [-- --json PATH]`
//! (`BENCH_prefix_cache.json` is regenerated this way.)

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{
    BatcherConfig, BlockManagerConfig, Engine, EngineConfig, FinishedRequest, PrefixCacheStats,
    Request,
};
use fa3_split::planner::Planner;
use fa3_split::util::alloc_counter::{self, CountingAllocator};
use fa3_split::util::json::Json;
use fa3_split::util::stats;
use fa3_split::workload::ChatWorkload;

#[global_allocator]
static COUNTER: CountingAllocator = CountingAllocator;

/// The sweep's serving stack: sequence-aware planner over the H100
/// model, 8 slots, and a deliberately tight 64-block (1024-token) KV
/// budget so admission is block-bound, not slot-bound.
fn engine(sharing: bool) -> Engine {
    let cfg = EngineConfig {
        batcher: BatcherConfig::for_max_batch(8),
        blocks: BlockManagerConfig {
            num_blocks: 64,
            max_seq: 1024,
            enable_prefix_sharing: sharing,
            ..Default::default()
        },
        ..Default::default()
    };
    Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(cfg)
        .build()
        .unwrap()
}

/// The sweep workload: 48 chats, a 256-token (16-block) system prompt
/// per fan-out group, short unique suffixes, fixed 16-token outputs.
fn sweep_workload(fanout: usize) -> ChatWorkload {
    ChatWorkload {
        seed: 0xBEEF,
        n_requests: 48,
        shared_prefix_len: 256,
        prefix_fanout: fanout,
        prompt_median: 48,
        prompt_min: 32,
        prompt_cap: 64,
        output_mean: 16,
        output_cap: 16,
        ..Default::default()
    }
}

struct SweepPoint {
    fanout: usize,
    mean_ttft_us: f64,
    p99_ttft_us: f64,
    wall_us: u64,
    tok_s: f64,
    stats: PrefixCacheStats,
}

fn run_sweep_point(fanout: usize) -> SweepPoint {
    let mut e = engine(true);
    for g in sweep_workload(fanout).generate() {
        e.submit_at(g.request, g.arrival_offset_us).expect("sweep shapes are schedulable");
    }
    let done = e.run_until_idle().unwrap();
    assert_eq!(done.len(), 48, "every request must finish");
    let ttfts: Vec<f64> = done.iter().map(|f| f.timing.ttft_us() as f64).collect();
    let (mean, p99) = stats::mean_p99(&ttfts);
    SweepPoint {
        fanout,
        mean_ttft_us: mean,
        p99_ttft_us: p99,
        wall_us: e.metrics.wall_us,
        tok_s: e.metrics.throughput_tok_s(),
        stats: e.metrics.prefix,
    }
}

/// Disjoint-identity leg: random traffic, sharing on vs off.
fn run_identity(sharing: bool) -> (Vec<FinishedRequest>, u64) {
    let workload = ChatWorkload {
        seed: 0xD15C0,
        n_requests: 32,
        prompt_median: 100,
        output_mean: 16,
        output_cap: 32,
        mean_gap_us: 300,
        ..Default::default()
    };
    let mut e = engine(sharing);
    for g in workload.generate() {
        e.submit_at(g.request, g.arrival_offset_us).expect("schedulable");
    }
    let mut done = e.run_until_idle().unwrap();
    done.sort_by_key(|f| f.id);
    (done, e.metrics.wall_us)
}

fn identical(a: &[FinishedRequest], b: &[FinishedRequest]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.id == y.id
                && x.tokens == y.tokens
                && x.reason == y.reason
                && x.timing.arrival_us == y.timing.arrival_us
                && x.timing.first_token_us == y.timing.first_token_us
                && x.timing.finished_us == y.timing.finished_us
        })
}

fn point_json(p: &SweepPoint) -> Json {
    Json::obj(vec![
        ("fanout", Json::int(p.fanout as i64)),
        ("mean_ttft_us", Json::num(p.mean_ttft_us)),
        ("p99_ttft_us", Json::num(p.p99_ttft_us)),
        ("wall_us", Json::int(p.wall_us as i64)),
        ("tok_s", Json::num(p.tok_s)),
        ("prefix_hit_rate", Json::num(p.stats.hit_rate())),
        ("blocks_saved", Json::int(p.stats.blocks_saved() as i64)),
        ("tokens_cached", Json::int(p.stats.tokens_cached as i64)),
        ("cow_forks", Json::int(p.stats.cow_forks as i64)),
    ])
}

fn main() {
    let json_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter().position(|a| a == "--json").and_then(|i| args.get(i + 1).cloned())
    };

    println!("== Prefix-sharing KV cache (shared vs disjoint at equal budget) ==\n");

    // ------------------------------------------------------------------
    // Scenario 1: fan-out sweep.
    // ------------------------------------------------------------------
    let points: Vec<SweepPoint> = [1usize, 2, 4, 8, 16].iter().map(|&f| run_sweep_point(f)).collect();
    println!("fanout |  mean TTFT µs |  p99 TTFT µs |   wall µs |   tok/s | hit-rate | saved");
    for p in &points {
        println!(
            "{:>6} | {:>13.1} | {:>12.1} | {:>9} | {:>7.0} | {:>7.1}% | {:>5}",
            p.fanout,
            p.mean_ttft_us,
            p.p99_ttft_us,
            p.wall_us,
            p.tok_s,
            p.stats.hit_rate() * 100.0,
            p.stats.blocks_saved()
        );
    }
    let disjoint = &points[0];
    let shared = points.iter().find(|p| p.fanout == 8).unwrap();

    // ------------------------------------------------------------------
    // Scenario 2: disjoint identity (sharing must be free when unused).
    // ------------------------------------------------------------------
    let (with, wall_with) = run_identity(true);
    let (without, wall_without) = run_identity(false);
    let id_ok = identical(&with, &without) && wall_with == wall_without;
    println!(
        "\ndisjoint identity: sharing on vs off over {} random requests — {}",
        with.len(),
        if id_ok { "byte-identical" } else { "DIVERGED" }
    );

    // ------------------------------------------------------------------
    // Scenario 3: steady-state decode allocations with sharing active.
    // ------------------------------------------------------------------
    let mut e = engine(true);
    // Two requests sharing one prefix, long generations: the measured
    // window holds a steady decode batch whose admission took the
    // sharing path (probe, attach, COW arm + fork all happened). The
    // second prompt stops mid-block inside the donor's full block 16,
    // so its admission arms a copy-on-write share and its first decode
    // token forks it — all during warmup.
    let donor: Vec<i32> = (0..272).map(|i| 7_000 + i).collect(); // 17 full blocks
    let tail_sharer = donor[..261].to_vec(); // 16 full + a 5-token tail
    drop(e.submit(Request::new(0, donor, 300)).unwrap());
    drop(e.submit(Request::new(1, tail_sharer, 300)).unwrap());
    for _ in 0..32 {
        e.step().unwrap(); // warmup: admission, prefill, fork, scratch sizing
    }
    const MEASURED_STEPS: usize = 250;
    e.metrics.reserve_capacity(MEASURED_STEPS + 16, 16);
    let before = alloc_counter::total_allocations();
    for _ in 0..MEASURED_STEPS {
        e.step().unwrap();
    }
    let allocs = alloc_counter::total_allocations() - before;
    assert_eq!(e.metrics.prefix.cow_forks, 1, "the warmup fork must have fired");
    assert_eq!(e.running_len(), 2, "the window measured steady decode, not retirement");
    println!(
        "steady-state with sharing: {allocs} heap acquisitions over {MEASURED_STEPS} steps \
         (prefix {:?})",
        e.metrics.prefix
    );

    // ------------------------------------------------------------------
    // Gates.
    // ------------------------------------------------------------------
    let mut ok = true;

    let g1 = shared.mean_ttft_us < disjoint.mean_ttft_us;
    println!(
        "\nshared TTFT vs disjoint at equal KV budget: {:.1} µs vs {:.1} µs ({})",
        shared.mean_ttft_us,
        disjoint.mean_ttft_us,
        if g1 { "OK" } else { "MISS" }
    );
    ok &= g1;

    let g2 = shared.tok_s > disjoint.tok_s;
    println!(
        "shared admitted throughput vs disjoint: {:.0} tok/s vs {:.0} tok/s ({})",
        shared.tok_s,
        disjoint.tok_s,
        if g2 { "OK" } else { "MISS" }
    );
    ok &= g2;

    println!("disjoint no-regression (identity): {}", if id_ok { "OK" } else { "MISS" });
    ok &= id_ok;

    let g4 = allocs == 0;
    println!(
        "zero-alloc decode steady state with sharing: {allocs} allocs ({})",
        if g4 { "OK" } else { "MISS" }
    );
    ok &= g4;

    if let Some(path) = json_path {
        let report = Json::obj(vec![
            ("bench", Json::str("prefix_cache")),
            (
                "generated_by",
                Json::str("cargo bench --bench prefix_cache -- --json <path>"),
            ),
            ("measured", Json::Bool(true)),
            ("sweep", Json::arr(points.iter().map(point_json))),
            (
                "gates",
                Json::obj(vec![
                    ("shared_ttft_us", Json::num(shared.mean_ttft_us)),
                    ("disjoint_ttft_us", Json::num(disjoint.mean_ttft_us)),
                    ("shared_tok_s", Json::num(shared.tok_s)),
                    ("disjoint_tok_s", Json::num(disjoint.tok_s)),
                    ("disjoint_identity", Json::Bool(id_ok)),
                    ("steady_state_allocs", Json::int(allocs as i64)),
                ]),
            ),
            ("passed", Json::Bool(ok)),
        ]);
        match std::fs::write(&path, report.to_string_pretty()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }

    if !ok {
        std::process::exit(1);
    }
}
