//! Bench: serving-level A/B on the simulated H100 — the paper's kernel
//! effect projected through the full serving stack (admission, continuous
//! batching, prefill, split scheduling, streaming lifecycle) under four
//! workload regimes, including an open-loop Poisson soak.
//!
//! Both policies drive the same `ExecutionBackend` API end-to-end: every
//! request is submitted through `Engine::submit`/`submit_at`, streamed
//! through its `RequestHandle`, and measured by `coordinator/metrics.rs`
//! (TTFT/TPOT p50/p99 on the virtual clock).
//!
//! Run: `cargo bench --bench serving_ab [-- --json PATH]`
//! `--json` writes the machine-readable report (the committed
//! `BENCH_serving_ab.json` is regenerated this way).

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{Engine, RequestHandle, StreamEvent};
use fa3_split::coordinator::{BatcherConfig, EngineConfig};
use fa3_split::planner::Planner;
use fa3_split::util::json::Json;
use fa3_split::util::stats::Summary;
use fa3_split::util::table::{speedup, us, Align, Table};
use fa3_split::workload::ChatWorkload;

struct RunResult {
    ttft: Option<Summary>,
    tpot: Option<Summary>,
    throughput_tok_s: f64,
    finished: usize,
    streamed_tokens: usize,
}

fn run(planner: Planner, workload: &ChatWorkload, max_batch: usize, open_loop: bool) -> RunResult {
    let buckets: Vec<usize> = [1usize, 2, 4, 8].into_iter().filter(|&b| b <= max_batch).collect();
    let mut engine = Engine::builder(Box::new(SimBackend::h100()))
        .planner(planner)
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(EngineConfig {
            batcher: BatcherConfig { max_batch: *buckets.last().unwrap(), batch_buckets: buckets },
            ..Default::default()
        })
        .build()
        .unwrap();
    let mut handles: Vec<RequestHandle> = Vec::new();
    for g in workload.generate() {
        let res = if open_loop {
            engine.submit_at(g.request, g.arrival_offset_us)
        } else {
            engine.submit(g.request)
        };
        handles.push(res.expect("workload fits the engine"));
    }
    let done = engine.run_until_idle().unwrap();
    // Streaming consumption: every generated token went out on a handle.
    let streamed_tokens = handles
        .iter()
        .map(|h| {
            std::iter::from_fn(|| h.try_event())
                .filter(|ev| matches!(ev, StreamEvent::Token { .. }))
                .count()
        })
        .sum();
    assert_eq!(streamed_tokens, engine.metrics.tokens_generated, "stream/result skew");
    RunResult {
        ttft: engine.metrics.ttft(),
        tpot: engine.metrics.tpot(),
        throughput_tok_s: engine.metrics.throughput_tok_s(),
        finished: done.len(),
        streamed_tokens,
    }
}

fn summary_json(s: &Option<Summary>) -> Json {
    match s {
        Some(s) => Json::obj(vec![
            ("mean_us", Json::num(s.mean)),
            ("p50_us", Json::num(s.p50)),
            ("p99_us", Json::num(s.p99)),
        ]),
        None => Json::Null,
    }
}

fn result_json(name: &str, std: &RunResult, pat: &RunResult) -> Json {
    let speedup_mean = match (&std.tpot, &pat.tpot) {
        (Some(a), Some(b)) if b.mean > 0.0 => Json::num(a.mean / b.mean),
        _ => Json::Null,
    };
    Json::obj(vec![
        ("regime", Json::str(name)),
        ("standard_ttft", summary_json(&std.ttft)),
        ("standard_tpot", summary_json(&std.tpot)),
        ("standard_throughput_tok_s", Json::num(std.throughput_tok_s)),
        ("sequence_aware_ttft", summary_json(&pat.ttft)),
        ("sequence_aware_tpot", summary_json(&pat.tpot)),
        ("sequence_aware_throughput_tok_s", Json::num(pat.throughput_tok_s)),
        ("tpot_speedup_mean", speedup_mean),
        ("finished", Json::int(std.finished.min(pat.finished) as i64)),
        ("streamed_tokens", Json::int((std.streamed_tokens + pat.streamed_tokens) as i64)),
    ])
}

fn main() {
    let json_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter()
            .position(|a| a == "--json")
            .and_then(|i| args.get(i + 1).cloned())
    };

    println!("== Serving-level A/B (simulated H100; streaming lifecycle end-to-end) ==\n");
    let regimes: Vec<(&str, ChatWorkload, usize, bool)> = vec![
        (
            "paper regime: B=1 chat, prompts ~400",
            ChatWorkload {
                n_requests: 12,
                prompt_median: 400,
                output_mean: 96,
                output_cap: 96,
                seed: 0xAB,
                ..Default::default()
            },
            1,
            false,
        ),
        (
            "short chat: B=1, prompts ~150",
            ChatWorkload {
                n_requests: 12,
                prompt_median: 150,
                output_mean: 64,
                output_cap: 64,
                seed: 0xAC,
                ..Default::default()
            },
            1,
            false,
        ),
        (
            "batched: up to B=4, prompts ~400",
            ChatWorkload {
                n_requests: 12,
                prompt_median: 400,
                output_mean: 96,
                output_cap: 96,
                seed: 0xAD,
                ..Default::default()
            },
            4,
            false,
        ),
        (
            "open-loop soak: Poisson arrivals, B=1, prompts ~400",
            ChatWorkload {
                n_requests: 48,
                prompt_median: 400,
                output_mean: 96,
                output_cap: 96,
                mean_gap_us: 1_500,
                seed: 0xAE,
                ..Default::default()
            },
            1,
            true,
        ),
    ];

    let mut t = Table::new(&[
        "Workload",
        "Std TPOT p50",
        "Pat TPOT p50",
        "TPOT speedup",
        "Std TTFT p99",
        "Pat TTFT p99",
    ])
    .align(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    let mut rows = Vec::new();
    for (name, workload, max_batch, open_loop) in &regimes {
        let a = run(Planner::standard(), workload, *max_batch, *open_loop);
        let b = run(Planner::sequence_aware(), workload, *max_batch, *open_loop);
        let (a_tpot, b_tpot) = (
            a.tpot.as_ref().map(|s| s.p50).unwrap_or(0.0),
            b.tpot.as_ref().map(|s| s.p50).unwrap_or(0.0),
        );
        let mean_ratio = match (&a.tpot, &b.tpot) {
            (Some(x), Some(y)) if y.mean > 0.0 => x.mean / y.mean,
            _ => 0.0,
        };
        t.row(&[
            name.to_string(),
            us(a_tpot),
            us(b_tpot),
            speedup(mean_ratio),
            us(a.ttft.as_ref().map(|s| s.p99).unwrap_or(0.0)),
            us(b.ttft.as_ref().map(|s| s.p99).unwrap_or(0.0)),
        ]);
        rows.push(result_json(name, &a, &b));
    }
    t.print();
    println!(
        "\nExpected shape: a clear TPOT win in the paper regime (requests crossing\n\
         the L_K=385..512 bucket at B=1), ~1.00x for short chat (Guard 1 region)\n\
         and for batch-4 (tiles >= 4 — saturated boundary, Guard 2); the open-loop\n\
         soak shows the win surviving queueing + admission on Poisson traffic."
    );

    if let Some(path) = json_path {
        let report = Json::obj(vec![
            ("bench", Json::str("serving_ab")),
            ("generated_by", Json::str("cargo bench --bench serving_ab -- --json <path>")),
            ("measured", Json::Bool(true)),
            ("rows", Json::arr(rows)),
        ]);
        std::fs::write(&path, report.to_string()).expect("write json report");
        println!("\nwrote {path}");
    }
}
