//! Bench: serving-level A/B on the simulated H100 — the paper's kernel
//! effect projected through the full coordinator (continuous batching,
//! prefill, scheduling) under three workload regimes.
//!
//! Run: `cargo bench --bench serving_ab`

use fa3_split::coordinator::scheduler::AttnGeometry;
use fa3_split::coordinator::{BatcherConfig, Engine, EngineConfig};
use fa3_split::planner::Planner;
use fa3_split::sim::Simulator;
use fa3_split::util::table::{speedup, us, Align, Table};
use fa3_split::workload::ChatWorkload;

fn run(planner: Planner, workload: &ChatWorkload, max_batch: usize) -> f64 {
    let buckets: Vec<usize> = [1usize, 2, 4, 8].into_iter().filter(|&b| b <= max_batch).collect();
    let mut engine = Engine::with_simulator(
        Simulator::h100(),
        planner,
        AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 },
        vec![1, 3],
        EngineConfig {
            batcher: BatcherConfig { max_batch: *buckets.last().unwrap(), batch_buckets: buckets },
            ..Default::default()
        },
    );
    for g in workload.generate() {
        engine.submit(g.request);
    }
    engine.run_until_idle().unwrap();
    engine.metrics.tpot().map(|s| s.mean).unwrap_or(0.0)
}

fn main() {
    println!("== Serving-level A/B (simulated H100; attention TPOT per request) ==\n");
    let regimes = [
        (
            "paper regime: B=1 chat, prompts ~400",
            ChatWorkload {
                n_requests: 12,
                prompt_median: 400,
                output_mean: 96,
                output_cap: 96,
                seed: 0xAB,
                ..Default::default()
            },
            1usize,
        ),
        (
            "short chat: B=1, prompts ~150",
            ChatWorkload {
                n_requests: 12,
                prompt_median: 150,
                output_mean: 64,
                output_cap: 64,
                seed: 0xAC,
                ..Default::default()
            },
            1usize,
        ),
        (
            "batched: up to B=4, prompts ~400",
            ChatWorkload {
                n_requests: 12,
                prompt_median: 400,
                output_mean: 96,
                output_cap: 96,
                seed: 0xAD,
                ..Default::default()
            },
            4usize,
        ),
    ];

    let mut t = Table::new(&["Workload", "Std TPOT (µs)", "Patched TPOT (µs)", "Speedup"])
        .align(&[Align::Left, Align::Right, Align::Right, Align::Right]);
    for (name, workload, max_batch) in regimes {
        let a = run(Planner::standard(), &workload, max_batch);
        let b = run(Planner::sequence_aware(), &workload, max_batch);
        t.row(&[name.to_string(), us(a), us(b), speedup(a / b)]);
    }
    t.print();
    println!(
        "\nExpected shape: a clear win in the paper regime (requests crossing the\n\
         L_K=385..512 bucket at B=1), ~1.00x for short chat (guard 1 region) and\n\
         for batch-4 (tiles >= 4 — saturated boundary, Guard 2)."
    );
}
