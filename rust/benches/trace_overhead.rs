//! Bench: flight-recorder overhead on the serving hot path.
//!
//! The observability contract (DESIGN.md §Observability) is that tracing
//! is cheap enough to leave on: recording is a store into a pre-sized
//! ring plus bucket arithmetic, never an allocation, never a syscall.
//! This bench measures that claim rather than asserting it:
//!
//! * **Step-cost ratio** — interleaved A/B of a warmed steady-decode
//!   window, recorder on (ring small enough to wrap) vs off. Gate:
//!   median traced step cost ≤ 1.05× untraced.
//! * **Allocations** — the traced window under the counting allocator.
//!   Gate: zero heap acquisitions per step.
//! * **Identity** — a full traced run vs the same run untraced. Gate:
//!   byte-identical tokens, reasons, and timings (observation, not
//!   perturbation).
//! * **Exporters** — the Chrome trace parses as JSON with the trace-event
//!   envelope, and the Prometheus text exposition carries the occupancy
//!   histogram families. Gate: both schema checks pass.
//!
//! Run: `cargo bench --bench trace_overhead [-- --json PATH]`
//! (`BENCH_trace_overhead.json` is regenerated this way.)

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{Engine, EngineConfig, FinishedRequest, Request};
use fa3_split::obs;
use fa3_split::planner::Planner;
use fa3_split::util::alloc_counter::{self, CountingAllocator};
use fa3_split::util::json::Json;
use fa3_split::util::stats;
use fa3_split::workload::ChatWorkload;

#[global_allocator]
static COUNTER: CountingAllocator = CountingAllocator;

fn engine(trace_capacity: usize) -> Engine {
    Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 2048 })
        .config(EngineConfig { trace_capacity, ..Default::default() })
        .build()
        .unwrap()
}

const WARMUP_STEPS: usize = 24;
const MEASURED_STEPS: usize = 400;
const TRIALS: usize = 7;

/// A warmed steady-decode engine: 2 slots, long generations, scratch
/// sized, stream sinks latched dead.
fn warmed(trace_capacity: usize) -> Engine {
    let mut e = engine(trace_capacity);
    drop(e.submit(Request::new(1, vec![1; 350], 3_000)).unwrap());
    drop(e.submit(Request::new(2, vec![1; 350], 3_000)).unwrap());
    for _ in 0..WARMUP_STEPS {
        e.step().unwrap();
    }
    assert_eq!(e.running_len(), 2, "warmup should settle into steady decode");
    e.metrics.reserve_capacity(MEASURED_STEPS + 16, 16);
    e
}

/// Wall time of one steady-decode window, µs.
fn timed_window(e: &mut Engine) -> f64 {
    let t0 = std::time::Instant::now();
    for _ in 0..MEASURED_STEPS {
        e.step().unwrap();
    }
    t0.elapsed().as_secs_f64() * 1e6
}

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn full_run(trace_capacity: usize) -> (Engine, Vec<FinishedRequest>) {
    let mut e = engine(trace_capacity);
    let workload = ChatWorkload {
        seed: 0x0B5E,
        n_requests: 8,
        prompt_median: 200,
        output_mean: 24,
        output_cap: 48,
        mean_gap_us: 400,
        ..Default::default()
    };
    for g in workload.generate() {
        e.submit_at(g.request, g.arrival_offset_us).expect("schedulable");
    }
    let mut done = e.run_until_idle().unwrap();
    done.sort_by_key(|f| f.id);
    (e, done)
}

fn identical(a: &[FinishedRequest], b: &[FinishedRequest]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.id == y.id
                && x.tokens == y.tokens
                && x.reason == y.reason
                && x.timing.arrival_us == y.timing.arrival_us
                && x.timing.first_token_us == y.timing.first_token_us
                && x.timing.finished_us == y.timing.finished_us
        })
}

fn main() {
    let json_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter().position(|a| a == "--json").and_then(|i| args.get(i + 1).cloned())
    };

    println!("== Flight-recorder overhead on the decode hot path ==\n");

    // ------------------------------------------------------------------
    // Scenario 1: interleaved A/B step cost, recorder on vs off. The
    // 1024-event ring wraps inside every window (~3 events/step × 400
    // steps), so the measured cost is the overwrite steady state.
    // ------------------------------------------------------------------
    let mut on_us = Vec::with_capacity(TRIALS);
    let mut off_us = Vec::with_capacity(TRIALS);
    for _ in 0..TRIALS {
        let mut traced = warmed(1024);
        let mut untraced = warmed(0);
        on_us.push(timed_window(&mut traced));
        off_us.push(timed_window(&mut untraced));
        assert!(traced.recorder().dropped() > 0, "the window must wrap the ring");
    }
    let (on_med, off_med) = (median(on_us.clone()), median(off_us.clone()));
    let ratio = on_med / off_med;
    let per_step_on = on_med / MEASURED_STEPS as f64;
    let per_step_off = off_med / MEASURED_STEPS as f64;
    println!(
        "step cost over {TRIALS} trials x {MEASURED_STEPS} steps: \
         on {per_step_on:.3} µs/step, off {per_step_off:.3} µs/step, ratio {ratio:.4}"
    );

    // ------------------------------------------------------------------
    // Scenario 2: allocations in the traced window.
    // ------------------------------------------------------------------
    let mut traced = warmed(1024);
    let before = alloc_counter::total_allocations();
    for _ in 0..MEASURED_STEPS {
        traced.step().unwrap();
    }
    let allocs = alloc_counter::total_allocations() - before;
    println!("traced steady-state window: {allocs} heap acquisitions over {MEASURED_STEPS} steps");

    // ------------------------------------------------------------------
    // Scenario 3: identity — tracing must not perturb the run.
    // ------------------------------------------------------------------
    let (traced_engine, with) = full_run(8192);
    let (_, without) = full_run(0);
    let id_ok = identical(&with, &without);
    println!(
        "traced vs untraced over {} requests: {}",
        with.len(),
        if id_ok { "byte-identical" } else { "DIVERGED" }
    );

    // ------------------------------------------------------------------
    // Scenario 4: exporter schemas on the traced run.
    // ------------------------------------------------------------------
    let trace_json = obs::engine_trace(traced_engine.recorder(), "engine").to_string();
    let chrome_ok = match Json::parse(&trace_json) {
        Ok(Json::Obj(top)) => matches!(top.get("traceEvents"), Some(Json::Arr(e)) if !e.is_empty()),
        _ => false,
    };
    let mut traced_engine = traced_engine;
    let prom = traced_engine.metrics.to_prometheus();
    let prom_ok = prom.contains("# TYPE fa3_decode_occupancy_keyed histogram")
        && prom.contains("_bucket{")
        && prom.ends_with('\n')
        && prom.lines().all(|l| l.is_empty() || l.starts_with('#') || l.contains(' '));
    println!(
        "exporters: chrome {} ({} bytes), prometheus {} ({} bytes)",
        if chrome_ok { "OK" } else { "INVALID" },
        trace_json.len(),
        if prom_ok { "OK" } else { "INVALID" },
        prom.len()
    );

    // ------------------------------------------------------------------
    // Gates.
    // ------------------------------------------------------------------
    let mut ok = true;
    let g1 = ratio <= 1.05;
    println!("\nrecorder-on step cost within 1.05x of off: {ratio:.4} ({})", if g1 { "OK" } else { "MISS" });
    ok &= g1;
    let g2 = allocs == 0;
    println!("zero allocations per traced step: {allocs} ({})", if g2 { "OK" } else { "MISS" });
    ok &= g2;
    println!("token/timing identity with tracing on: {}", if id_ok { "OK" } else { "MISS" });
    ok &= id_ok;
    let g4 = chrome_ok && prom_ok;
    println!("exporter schemas valid: {}", if g4 { "OK" } else { "MISS" });
    ok &= g4;

    if let Some(path) = json_path {
        let report = Json::obj(vec![
            ("bench", Json::str("trace_overhead")),
            (
                "generated_by",
                Json::str("cargo bench --bench trace_overhead -- --json <path>"),
            ),
            ("measured", Json::Bool(true)),
            (
                "step_cost",
                Json::obj(vec![
                    ("trials", Json::int(TRIALS as i64)),
                    ("steps_per_trial", Json::int(MEASURED_STEPS as i64)),
                    ("on_us_per_step", Json::num(per_step_on)),
                    ("off_us_per_step", Json::num(per_step_off)),
                    ("ratio", Json::num(ratio)),
                    ("on_us_mean_p99", {
                        let (m, p) = stats::mean_p99(&on_us);
                        Json::obj(vec![("mean", Json::num(m)), ("p99", Json::num(p))])
                    }),
                ]),
            ),
            (
                "gates",
                Json::obj(vec![
                    ("ratio_limit", Json::num(1.05)),
                    ("ratio", Json::num(ratio)),
                    ("steady_state_allocs", Json::int(allocs as i64)),
                    ("identity", Json::Bool(id_ok)),
                    ("chrome_schema", Json::Bool(chrome_ok)),
                    ("prometheus_schema", Json::Bool(prom_ok)),
                ]),
            ),
            ("passed", Json::Bool(ok)),
        ]);
        match std::fs::write(&path, report.to_string_pretty()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }

    if !ok {
        std::process::exit(1);
    }
}
