//! Bench: disaggregated prefill/decode pools vs colocated serving at an
//! equal device count.
//!
//! The sequence-aware policy pays off almost exclusively in `q_len = 1`
//! decode steps, so a decode pool that does nothing else concentrates the
//! paper's `Batch × H_KV < 4` starved regime — prefill interference leaves
//! the pool entirely, at the price of one modeled KV transfer per request
//! across the cross-pool interconnect. This harness sweeps tp ∈ {1,2,4,8}
//! over the fixed 8-KV-head GQA model and, per TP point, runs the same
//! heavy-decode workload four ways on two devices:
//!
//! * colocated (2 unified replicas, session-affinity) × {standard,
//!   sequence-aware} — advantage read off the pooled end-to-end TPOT,
//! * disaggregated (1 prefill + 1 decode replica, two-stage router,
//!   InfiniBand link) × {standard, sequence-aware} — advantage read off
//!   the decode-pool TPOT (decode-side step time; wire time and prefill
//!   interference excluded, since those are policy-independent costs).
//!
//! Gates (exit 1 on failure):
//!
//! * the sequence-aware advantage in the decode pool survives
//!   disaggregation at every TP point (≥ colocated advantage − 0.01) and
//!   never shrinks as tp grows,
//! * a zero-cost link (1P+1D, `--xfer zero`) serves byte-identical token
//!   streams to a colocated single replica — the handoff machinery itself
//!   must not perturb generation (position-pure synthetic tokens),
//! * the two-stage router on a *colocated* topology collapses to plain
//!   session-affinity (identical assignments and streams),
//! * every run drains its transfer ledger: handoffs delivered, none
//!   cancelled, conservation `begun = delivered + cancelled` intact.
//!
//! Run: `cargo bench --bench disaggregation [-- --json PATH]`
//! (`BENCH_disaggregation.json` is regenerated with `--json`.)

use fa3_split::backend::AttnGeometry;
use fa3_split::cluster::{
    router, ClusterTopology, Fleet, FleetConfig, FleetReport, Interconnect, ReplicaRole, Router,
    TpConfig,
};
use fa3_split::coordinator::{BatcherConfig, EngineConfig};
use fa3_split::planner::DeviceProfile;
use fa3_split::util::json::Json;
use fa3_split::util::table::{speedup, us, Align, Table};
use fa3_split::workload::ChatWorkload;

/// Full-model attention geometry (Llama-3.1-70B: 64 Q heads, 8 KV heads).
const MODEL: AttnGeometry = AttnGeometry { h_q: 64, h_kv: 8, d: 128, max_seq: 1024 };
const TP_DEGREES: [usize; 4] = [1, 2, 4, 8];
const N_REQUESTS: usize = 16;
const SEED: u64 = 0xD15A;

/// Heavy-decode chat pinned to the L_K=385..512 boundary bucket, where
/// the sequence-aware window opens at low per-shard head count.
fn heavy_decode(seed: u64, n_requests: usize) -> ChatWorkload {
    ChatWorkload::boundary_bucket(seed, n_requests, 96)
}

fn engine_cfg() -> EngineConfig {
    EngineConfig { batcher: BatcherConfig::for_max_batch(4), ..Default::default() }
}

fn colocated_topology(tp: usize, replicas: usize) -> ClusterTopology {
    ClusterTopology::builder(MODEL)
        .tp(TpConfig::new(tp))
        .replicas(replicas, DeviceProfile::H100_SXM)
        .build()
        .expect("valid colocated topology")
}

fn split_topology(tp: usize, link: Interconnect) -> ClusterTopology {
    ClusterTopology::builder(MODEL)
        .tp(TpConfig::new(tp))
        .pool(1, DeviceProfile::H100_SXM, ReplicaRole::Prefill)
        .pool(1, DeviceProfile::H100_SXM, ReplicaRole::Decode)
        .interconnect(link)
        .build()
        .expect("valid split topology")
}

fn run(
    topology: ClusterTopology,
    policy: &str,
    router: Box<dyn Router>,
    workload: &ChatWorkload,
) -> FleetReport {
    let mut fleet = Fleet::new(
        topology,
        router,
        FleetConfig::default().policy(policy).engine(engine_cfg()),
    )
    .expect("fleet builds");
    fleet.run(&workload.generate()).expect("fleet run completes")
}

/// One TP point: colocated and disaggregated, each under both policies.
struct SweepRow {
    tp: usize,
    shard_h_kv: usize,
    coloc_std: FleetReport,
    coloc_seq: FleetReport,
    disagg_std: FleetReport,
    disagg_seq: FleetReport,
}

fn tpot_mean(report: &FleetReport) -> f64 {
    report.tpot.as_ref().map(|s| s.mean).unwrap_or(0.0)
}

fn decode_tpot_mean(report: &FleetReport) -> f64 {
    report.decode_pool_tpot.as_ref().map(|s| s.mean).unwrap_or(0.0)
}

impl SweepRow {
    /// Colocated advantage: standard / sequence-aware end-to-end TPOT.
    fn coloc_advantage(&self) -> f64 {
        ratio(tpot_mean(&self.coloc_std), tpot_mean(&self.coloc_seq))
    }

    /// Decode-pool advantage: standard / sequence-aware decode-side TPOT.
    /// Wire time is excluded — the transfer cost is policy-independent,
    /// so including it would only dilute the measured planner effect.
    fn disagg_advantage(&self) -> f64 {
        ratio(decode_tpot_mean(&self.disagg_std), decode_tpot_mean(&self.disagg_seq))
    }
}

fn ratio(a: f64, b: f64) -> f64 {
    if b > 0.0 {
        a / b
    } else {
        0.0
    }
}

fn sweep() -> Vec<SweepRow> {
    TP_DEGREES
        .iter()
        .map(|&tp| {
            let workload = heavy_decode(SEED, N_REQUESTS);
            let coloc = |policy: &str| {
                run(
                    colocated_topology(tp, 2),
                    policy,
                    Box::new(router::SessionAffinity::default()),
                    &workload,
                )
            };
            let disagg = |policy: &str| {
                run(
                    split_topology(tp, Interconnect::INFINIBAND),
                    policy,
                    Box::new(router::Disaggregated::default()),
                    &workload,
                )
            };
            SweepRow {
                tp,
                shard_h_kv: MODEL.h_kv / tp,
                coloc_std: coloc("standard"),
                coloc_seq: coloc("sequence-aware"),
                disagg_std: disagg("standard"),
                disagg_seq: disagg("sequence-aware"),
            }
        })
        .collect()
}

/// Per-request `(id, reason, tokens)` signature for stream identity.
fn stream_signature(report: &FleetReport) -> Vec<(u64, String, Vec<i32>)> {
    let mut sig: Vec<(u64, String, Vec<i32>)> = report
        .finished
        .iter()
        .map(|f| (f.id, format!("{:?}", f.reason), f.tokens.clone()))
        .collect();
    sig.sort();
    sig
}

/// Zero-cost identity: a free link must leave the token streams exactly
/// as a colocated single replica produces them.
struct IdentityCheck {
    coloc: FleetReport,
    zero: FleetReport,
}

fn zero_cost_identity(tp: usize) -> IdentityCheck {
    let workload = heavy_decode(SEED ^ 0xF, N_REQUESTS);
    let coloc = run(
        colocated_topology(tp, 1),
        "sequence-aware",
        Box::new(router::RoundRobin::new()),
        &workload,
    );
    let zero = run(
        split_topology(tp, Interconnect::ZERO),
        "sequence-aware",
        Box::new(router::Disaggregated::default()),
        &workload,
    );
    IdentityCheck { coloc, zero }
}

/// Collapsed pools: the two-stage router on a colocated topology must be
/// indistinguishable from its decode stage (plain session-affinity).
struct CollapseCheck {
    affinity: FleetReport,
    collapsed: FleetReport,
}

fn collapsed_pools(tp: usize) -> CollapseCheck {
    let workload = ChatWorkload { turns_per_session: 2, ..heavy_decode(SEED ^ 0xC0, N_REQUESTS) };
    let affinity = run(
        colocated_topology(tp, 2),
        "sequence-aware",
        Box::new(router::SessionAffinity::default()),
        &workload,
    );
    let collapsed = run(
        colocated_topology(tp, 2),
        "sequence-aware",
        Box::new(router::Disaggregated::default()),
        &workload,
    );
    CollapseCheck { affinity, collapsed }
}

/// The acceptance gate (mirrored in tests/disaggregation.rs): the
/// sequence-aware advantage must survive the move into the decode pool
/// at every TP point, and the handoff machinery must be invisible in the
/// token streams and leak-free in the ledger.
fn verify(rows: &[SweepRow], ident: &IdentityCheck, collapse: &CollapseCheck) -> Result<(), String> {
    for r in rows {
        if r.disagg_advantage() < r.coloc_advantage() - 0.01 {
            return Err(format!(
                "tp={}: decode-pool advantage {:.3}x fell below colocated {:.3}x",
                r.tp,
                r.disagg_advantage(),
                r.coloc_advantage()
            ));
        }
        for (label, rep) in [
            ("coloc/std", &r.coloc_std),
            ("coloc/seq", &r.coloc_seq),
            ("disagg/std", &r.disagg_std),
            ("disagg/seq", &r.disagg_seq),
        ] {
            if rep.finished.len() != N_REQUESTS || rep.rejected != 0 {
                return Err(format!(
                    "tp={} {label}: served {}/{N_REQUESTS}, rejected {}",
                    r.tp,
                    rep.finished.len(),
                    rep.rejected
                ));
            }
        }
        for rep in [&r.disagg_std, &r.disagg_seq] {
            if rep.handoffs == 0 {
                return Err(format!("tp={}: disaggregated run delivered no handoffs", r.tp));
            }
            if rep.handoffs_cancelled != 0 {
                return Err(format!(
                    "tp={}: {} handoffs cancelled under nominal load",
                    r.tp, rep.handoffs_cancelled
                ));
            }
            if rep.transferred_blocks == 0 || rep.transfer_wire_us == 0 {
                return Err(format!(
                    "tp={}: transfer ledger empty (blocks={}, wire_us={})",
                    r.tp, rep.transferred_blocks, rep.transfer_wire_us
                ));
            }
        }
    }
    for w in rows.windows(2) {
        if w[1].disagg_advantage() < w[0].disagg_advantage() - 0.01 {
            return Err(format!(
                "decode-pool advantage shrank from tp={} ({:.3}x) to tp={} ({:.3}x)",
                w[0].tp,
                w[0].disagg_advantage(),
                w[1].tp,
                w[1].disagg_advantage()
            ));
        }
    }
    let tp8 = rows.last().expect("tp=8 row");
    if tp8.disagg_advantage() < 1.05 {
        return Err(format!(
            "tp=8 decode-pool advantage too small: {:.3}x",
            tp8.disagg_advantage()
        ));
    }
    // Zero-cost link: the handoff must be invisible in the streams.
    if stream_signature(&ident.coloc) != stream_signature(&ident.zero) {
        return Err("zero-cost disaggregated streams diverged from colocated".into());
    }
    if ident.zero.transfer_wire_us != 0 {
        return Err(format!(
            "zero link accrued {}us of wire time",
            ident.zero.transfer_wire_us
        ));
    }
    // Collapsed pools: two-stage router degenerates to session-affinity.
    if collapse.affinity.assignments != collapse.collapsed.assignments {
        return Err("collapsed two-stage router placed requests differently".into());
    }
    if stream_signature(&collapse.affinity) != stream_signature(&collapse.collapsed) {
        return Err("collapsed two-stage router perturbed the token streams".into());
    }
    if collapse.collapsed.handoffs != 0 || collapse.collapsed.transferred_blocks != 0 {
        return Err("colocated topology recorded phantom handoffs".into());
    }
    Ok(())
}

fn row_json(r: &SweepRow) -> Json {
    Json::obj(vec![
        ("tp_degree", Json::int(r.tp as i64)),
        ("shard_h_kv", Json::int(r.shard_h_kv as i64)),
        ("coloc_standard_tpot_us", Json::num(tpot_mean(&r.coloc_std))),
        ("coloc_sequence_aware_tpot_us", Json::num(tpot_mean(&r.coloc_seq))),
        ("coloc_advantage", Json::num(r.coloc_advantage())),
        ("decode_pool_standard_tpot_us", Json::num(decode_tpot_mean(&r.disagg_std))),
        ("decode_pool_sequence_aware_tpot_us", Json::num(decode_tpot_mean(&r.disagg_seq))),
        ("decode_pool_advantage", Json::num(r.disagg_advantage())),
        ("handoffs_delivered", Json::int(r.disagg_seq.handoffs as i64)),
        ("transferred_blocks", Json::int(r.disagg_seq.transferred_blocks as i64)),
        ("transfer_wire_us", Json::int(r.disagg_seq.transfer_wire_us as i64)),
        (
            "decode_pool_occupancy_sequence_aware",
            Json::num(r.disagg_seq.pool_mean_occupancy(ReplicaRole::Decode)),
        ),
        (
            "decode_pool_occupancy_standard",
            Json::num(r.disagg_std.pool_mean_occupancy(ReplicaRole::Decode)),
        ),
    ])
}

fn print_sweep(rows: &[SweepRow]) {
    let mut t = Table::new(&[
        "tp",
        "H_KV/shard",
        "Coloc adv",
        "Pool Std TPOT",
        "Pool Seq TPOT",
        "Pool adv",
        "Handoffs",
        "Wire us",
    ])
    .align(&[
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for r in rows {
        t.row(&[
            r.tp.to_string(),
            r.shard_h_kv.to_string(),
            speedup(r.coloc_advantage()),
            us(decode_tpot_mean(&r.disagg_std)),
            us(decode_tpot_mean(&r.disagg_seq)),
            speedup(r.disagg_advantage()),
            r.disagg_seq.handoffs.to_string(),
            r.disagg_seq.transfer_wire_us.to_string(),
        ]);
    }
    t.print();
}

fn main() {
    let json_path = {
        let args: Vec<String> = std::env::args().collect();
        args.iter().position(|a| a == "--json").and_then(|i| args.get(i + 1).cloned())
    };

    println!(
        "== Disaggregation: 1P+1D (InfiniBand) vs 2 colocated replicas, 8-KV-head model =="
    );
    let rows = sweep();
    print_sweep(&rows);

    println!("\n== Identity checks ==");
    let ident = zero_cost_identity(8);
    let ident_ok = stream_signature(&ident.coloc) == stream_signature(&ident.zero);
    println!(
        "zero-cost link vs colocated single replica: {} ({} streams)",
        if ident_ok { "byte-identical" } else { "DIVERGED" },
        ident.zero.finished.len()
    );
    let collapse = collapsed_pools(8);
    let collapse_ok = collapse.affinity.assignments == collapse.collapsed.assignments;
    println!(
        "collapsed pools vs session-affinity: {} ({} assignments)",
        if collapse_ok { "identical placement" } else { "DIVERGED" },
        collapse.collapsed.assignments.len()
    );

    let verdict = verify(&rows, &ident, &collapse);
    if let Some(path) = &json_path {
        let report = Json::obj(vec![
            ("bench", Json::str("disaggregation")),
            (
                "regenerate_with",
                Json::str("cargo bench --bench disaggregation -- --json BENCH_disaggregation.json"),
            ),
            ("measured", Json::Bool(true)),
            (
                "config",
                Json::obj(vec![
                    ("requests", Json::int(N_REQUESTS as i64)),
                    ("devices_per_arm", Json::int(2)),
                    ("interconnect", Json::str("infiniband")),
                    ("h_kv", Json::int(MODEL.h_kv as i64)),
                ]),
            ),
            ("tp_sweep", Json::arr(rows.iter().map(row_json))),
            (
                "identity",
                Json::obj(vec![
                    ("zero_cost_streams_byte_identical", Json::Bool(ident_ok)),
                    ("collapsed_pools_match_session_affinity", Json::Bool(collapse_ok)),
                ]),
            ),
            ("passed", Json::Bool(verdict.is_ok())),
        ]);
        std::fs::write(path, report.to_string_pretty()).expect("write json report");
        println!("\nwrote {path}");
    }
    match verdict {
        Ok(()) => println!(
            "\nOK: the sequence-aware advantage survives disaggregation and the handoff \
             machinery is stream-invisible and leak-free"
        ),
        Err(msg) => {
            eprintln!("\nFAILED: {msg}");
            std::process::exit(1);
        }
    }
}
