//! Bench: real PJRT execution latency of the AOT artifacts on the CPU
//! backend — the end-to-end request path the rust coordinator drives.
//!
//! CPU absolute times are NOT the paper's H100 numbers (the simulator
//! reproduces those); this bench tracks the *runtime's* cost structure:
//! kernel execute, decode-step execute with persistent weights, and the
//! one-time weight upload. Requires `make artifacts`.
//!
//! Run: `cargo bench --bench runtime_exec`

use fa3_split::bench_harness::Bencher;
use fa3_split::runtime::{HostTensor, Registry};
use fa3_split::util::prng::Rng;
use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built — run `make artifacts` first");
        return;
    }
    let reg = Registry::open(&dir).expect("open registry");
    let mut rng = Rng::new(0xBE7C);

    println!("== PJRT runtime execution (CPU backend; structure, not H100 absolutes) ==\n");
    let b = Bencher { warmup_iters: 5, samples: 30, batch_iters: 3 };

    // Attention kernel artifacts: s = 1 vs s = 3 at the paper shape.
    let rand = |rng: &mut Rng, shape: &[usize]| {
        let n: usize = shape.iter().product();
        HostTensor::f32(shape, (0..n).map(|_| rng.normal() as f32).collect()).unwrap()
    };
    let q = rand(&mut rng, &[1, 8, 128]);
    let k = rand(&mut rng, &[1, 512, 1, 128]);
    let v = rand(&mut rng, &[1, 512, 1, 128]);
    let lens = HostTensor::s32(&[1], vec![512]).unwrap();
    for s in [1usize, 3] {
        if let Some(entry) = reg.manifest.find_kernel(1, 512, 1, s) {
            let exe = reg.executor_for(entry).expect("compile");
            let args = [q.clone(), k.clone(), v.clone(), lens.clone()];
            b.run(&format!("attn kernel L_K=512 s={s}       (execute)"), || {
                exe.execute(&args).unwrap()
            });
        }
    }

    // Weight upload (one-time cost) + decode step with persistent weights.
    if reg.manifest.model.is_some() {
        let t0 = std::time::Instant::now();
        let weights = reg.weights().expect("weights");
        println!(
            "weights: {} params uploaded once in {:.1} ms",
            weights.len(),
            t0.elapsed().as_secs_f64() * 1e3
        );

        if let Some(entry) = reg.manifest.find_decode_bucket(1, 1) {
            let cfg = &reg.manifest.model.as_ref().unwrap().config;
            let bsz = entry.meta.batch.unwrap();
            let cache_shape =
                [cfg.n_layers, bsz, cfg.max_seq, cfg.n_heads_kv, cfg.head_dim];
            let tokens = HostTensor::s32(&[bsz], vec![1; bsz]).unwrap();
            let positions = HostTensor::s32(&[bsz], vec![0; bsz]).unwrap();
            let kv_k = HostTensor::zeros_f32(&cache_shape);
            let kv_v = HostTensor::zeros_f32(&cache_shape);
            let name = entry.name.clone();
            let heavy = Bencher::heavy();
            heavy.run("model decode step b=1 s=1      (execute_model)", || {
                reg.execute_model(
                    &name,
                    &[tokens.clone(), positions.clone(), kv_k.clone(), kv_v.clone()],
                )
                .unwrap()
            });
        }
    }
    println!("\nOK");
}
