//! The zero-allocation decode hot-path guarantee, held under a counting
//! global allocator (DESIGN.md §Decode hot path).
//!
//! A warmed-up engine decoding a steady batch must perform **zero** heap
//! allocations per step: the step plan, batch rows, outcome buffers, and
//! retirement list are engine scratch; the split decision rides the
//! scheduler's `PlanCursor`; per-request token buffers are pre-sized at
//! admission; and cursor refills at nblk bucket edges stay on the
//! guard-path decision (allocation-free since the efficiency loop dropped
//! its per-call Vec).
//!
//! This file holds a single `#[test]`: the allocation counter is
//! process-global, so the measured window must not race another test's
//! allocations in the same binary.

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{Engine, Request};
use fa3_split::planner::Planner;
use fa3_split::util::alloc_counter::{self, CountingAllocator};

#[global_allocator]
static COUNTER: CountingAllocator = CountingAllocator;

#[test]
fn steady_state_decode_step_allocates_nothing_after_warmup() {
    let mut engine = Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 2048 })
        .build()
        .unwrap();
    // Fire-and-forget submissions: the handles are dropped, so the stream
    // sink latches dead on its first send and token streaming costs
    // nothing per step. (Live streaming consumers pay mpsc channel
    // blocks; that is the channel's cost, not the step loop's.)
    drop(engine.submit(Request::new(1, vec![1; 350], 400)).unwrap());
    drop(engine.submit(Request::new(2, vec![1; 350], 400)).unwrap());

    // Warmup: admission + prefill + enough decode steps to size every
    // scratch buffer and latch the dead sinks.
    for _ in 0..24 {
        engine.step().unwrap();
    }
    assert!(engine.waiting_len() == 0 && engine.running_len() == 2, "warmup should settle");
    // Pre-grow the metrics sample buffers for the measured window.
    engine.metrics.reserve_capacity(256, 16);

    let cursor_before = engine.cursor_stats();
    let before = alloc_counter::total_allocations();
    // 100 steps from KV ≈ 373: crosses the 384/385 nblk edge mid-window,
    // so the measurement also proves a cursor refill (and the
    // sequence-aware boundary override it installs) is allocation-free.
    for _ in 0..100 {
        engine.step().unwrap();
    }
    let allocated = alloc_counter::total_allocations() - before;
    let cursor = engine.cursor_stats();

    assert_eq!(
        allocated, 0,
        "steady-state decode steps must not allocate (got {allocated} over 100 steps)"
    );
    // The window really rode the cursor: ~99 hits, >= 1 refill at the
    // bucket edge.
    assert!(
        cursor.hits > cursor_before.hits + 90,
        "cursor not engaged: {cursor_before:?} -> {cursor:?}"
    );
    assert!(cursor.refills >= cursor_before.refills + 1, "bucket edge should refill: {cursor:?}");
    // The batch is still mid-generation (the window measured steady
    // state, not retirement), and the paper's boundary override fired.
    assert_eq!(engine.running_len(), 2);
    assert!(engine.metrics.split_histogram.get(3).copied().unwrap_or(0) > 0);

    // Sanity: the generation still completes correctly afterwards.
    let done = engine.run_until_idle().unwrap();
    assert_eq!(done.len(), 2);
    assert!(done.iter().all(|f| f.tokens.len() == 400));
}
