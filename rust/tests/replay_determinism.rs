//! ReplayBackend determinism: a recorded step trace replayed through a
//! fresh engine must reproduce the run exactly — same tokens, same
//! timings, identical `EngineMetrics` — and any divergence between the
//! replaying engine and the trace must fail loudly.
//!
//! The `soak` test is `#[ignore]`d for normal runs and executed by the CI
//! replay gate (`cargo test --release --test replay_determinism --
//! --include-ignored`).

use fa3_split::backend::{AttnGeometry, ExecutionBackend, ReplayBackend, SimBackend, StepTrace};
use fa3_split::coordinator::{Engine, EngineConfig, EngineMetrics, FinishedRequest};
use fa3_split::planner::Planner;
use fa3_split::workload::ChatWorkload;

fn build_engine(backend: Box<dyn ExecutionBackend>) -> Engine {
    Engine::builder(backend)
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(EngineConfig::default())
        .build()
        .unwrap()
}

fn workload(n: usize, seed: u64) -> ChatWorkload {
    ChatWorkload {
        seed,
        n_requests: n,
        prompt_median: 300,
        output_mean: 24,
        output_cap: 48,
        ..Default::default()
    }
}

/// Everything that must be bit-identical across record and replay.
fn snapshot(m: &EngineMetrics, done: &[FinishedRequest]) -> String {
    let mut requests: Vec<String> = done
        .iter()
        .map(|f| {
            format!(
                "{}:{:?}:{:?}:{}:{}:{}",
                f.id, f.reason, f.tokens, f.timing.ttft_us(), f.timing.finished_us,
                f.timing.n_generated
            )
        })
        .collect();
    requests.sort();
    format!(
        "steps={} decode={} prefill={} tokens={} finished={} hist={:?} wall={} \
         tpot={:?} ttft={:?}\n{}",
        m.steps,
        m.decode_steps,
        m.prefill_calls,
        m.tokens_generated,
        m.requests_finished,
        m.split_histogram,
        m.wall_us,
        m.tpot(),
        m.ttft(),
        requests.join("\n")
    )
}

fn record_run(n: usize, seed: u64) -> (String, StepTrace) {
    let (backend, trace) = ReplayBackend::recorder(Box::new(SimBackend::h100()));
    let mut engine = build_engine(Box::new(backend));
    for g in workload(n, seed).generate() {
        engine.submit(g.request).unwrap();
    }
    let done = engine.run_until_idle().unwrap();
    let snap = snapshot(&engine.metrics, &done);
    let trace = trace.lock().unwrap().clone();
    (snap, trace)
}

fn replay_run(trace: StepTrace, n: usize, seed: u64) -> anyhow::Result<String> {
    let mut engine = build_engine(Box::new(ReplayBackend::replay(trace)));
    for g in workload(n, seed).generate() {
        engine
            .submit(g.request)
            .map_err(|e| anyhow::anyhow!("refused: {e}"))?;
    }
    let done = engine.run_until_idle()?;
    Ok(snapshot(&engine.metrics, &done))
}

#[test]
fn same_trace_means_identical_engine_metrics() {
    let (recorded, trace) = record_run(6, 0xD1CE);
    let replayed = replay_run(trace.clone(), 6, 0xD1CE).unwrap();
    assert_eq!(recorded, replayed, "replay diverged from the recorded run");
    // Replaying twice is just as deterministic.
    let replayed_again = replay_run(trace, 6, 0xD1CE).unwrap();
    assert_eq!(recorded, replayed_again);
}

#[test]
fn replay_detects_a_different_workload() {
    let (_, trace) = record_run(6, 0xD1CE);
    // Different seed => different prompts => the engine prepares different
    // steps than the trace recorded: must error, not silently replay.
    let err = replay_run(trace, 6, 0xBEEF).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("divergence") || msg.contains("exhausted"),
        "unexpected error: {msg}"
    );
}

#[test]
fn replay_detects_a_truncated_trace() {
    let (_, mut trace) = record_run(4, 7);
    assert!(trace.len() > 4);
    trace.records.truncate(trace.len() / 2);
    let err = replay_run(trace, 4, 7).unwrap_err();
    assert!(format!("{err:#}").contains("exhausted"), "{err:#}");
}

/// CI soak gate: a larger open-loop-style run recorded once and replayed
/// repeatedly; every replay must be bit-identical. `#[ignore]` keeps it
/// out of the default `cargo test` wall time.
#[test]
#[ignore]
fn soak_record_replay_stays_identical() {
    let (recorded, trace) = record_run(64, 0x50AC);
    assert!(trace.len() > 300, "soak should cover many steps, got {}", trace.len());
    for round in 0..5 {
        let replayed = replay_run(trace.clone(), 64, 0x50AC).unwrap();
        assert_eq!(recorded, replayed, "replay round {round} diverged");
    }
}
