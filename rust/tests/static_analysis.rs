//! Integration tests for pallas-lint: the fixture corpus, the real tree
//! staying clean, and the model checker's quick domain.
//!
//! The full-domain model check runs in CI via `fa3-split lint`; here we
//! use [`ModelCheckConfig::quick`] so the suite stays debug-build fast.

use std::path::{Path, PathBuf};

use fa3_split::analysis::source::{bench_manifest, run_source_passes, SourceSet};
use fa3_split::analysis::{self, fixtures, modelcheck, LintOptions, ModelCheckConfig, Severity};
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::planner::{DeviceProfile, PolicyRegistry};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("crate lives under repo root").into()
}

#[test]
fn fixture_corpus_passes() {
    // Every seeded violation fires its pass (and only its pass), and the
    // clean fixture stays clean — the same corpus `lint --fixtures` runs.
    let mut findings = Vec::new();
    let checked = fixtures::verify(&mut findings);
    assert!(checked >= 6, "corpus unexpectedly small: {checked}");
    assert!(findings.is_empty(), "{findings:#?}");
}

#[test]
fn real_tree_is_clean_under_source_passes() {
    // Self-hosting: the lint runs over its own repository and finds
    // nothing. Anything it flags is either a real architecture violation
    // (fix the code) or a false positive (fix the lint) — both block.
    let set = SourceSet::load_dir(&repo_root().join("rust").join("src")).expect("src tree");
    let mut findings = Vec::new();
    let stats = run_source_passes(&set, &mut findings);
    assert!(findings.is_empty(), "{findings:#?}");
    // The scan actually covered the tree (0 findings != 0 files).
    assert!(stats.files_scanned > 60, "only {} files scanned", stats.files_scanned);
    assert!(stats.use_edges > 50, "only {} use edges", stats.use_edges);
    assert!(stats.literal_sites > 250, "only {} literal sites", stats.literal_sites);
    assert!(stats.no_alloc_regions >= 8, "only {} no_alloc regions", stats.no_alloc_regions);
    // The one reviewed exception (capacity-0 Vec::new placeholder).
    assert_eq!(stats.suppressed, 1);
}

#[test]
fn real_tree_bench_manifests_are_wired() {
    let inputs = bench_manifest::BenchManifestInputs::load(&repo_root()).expect("repo root");
    let mut findings = Vec::new();
    let manifests = bench_manifest::check(&inputs, &mut findings);
    assert!(manifests >= 5, "expected the checked-in BENCH_*.json set, got {manifests}");
    // Modeled-targets warnings are expected until a real toolchain run;
    // errors (orphaned / undocumented / un-CI'd manifests) are not.
    let errors: Vec<_> =
        findings.iter().filter(|f| f.severity == Severity::Error).collect();
    assert!(errors.is_empty(), "{errors:#?}");
}

#[test]
fn model_checker_quick_domain_holds() {
    let cfg = ModelCheckConfig::quick();
    let report = modelcheck::check(&cfg);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
    assert!(report.no_regression_domain > 500);
    assert!(report.strict_improvements > 0, "boundary bucket never exercised");
    assert!(report.cursor_plans > 1_000);
}

#[test]
fn model_checker_spot_pins_known_good_triples() {
    // Independent of the checker: pin the paper's headline cells so a
    // substrate drift fails loudly here, not as a modelcheck violation.
    let registry = PolicyRegistry::builtin();
    let h100 = DeviceProfile::H100_SXM;
    let shape = DecodeShape::llama70b_tp8(1, 512);

    let mut std_p = registry.builder_for("standard", &h100).unwrap().build();
    let std_plan = std_p.plan(&shape);
    assert_eq!(std_plan.num_splits(), 1, "premature guard");
    assert!((std_plan.occupancy - 1.0 / 132.0).abs() < 1e-12);

    let mut seq_p = registry.builder_for("sequence-aware", &h100).unwrap().build();
    let seq_plan = seq_p.plan(&shape);
    assert_eq!(seq_plan.num_splits(), 3, "boundary override");
    assert_eq!(seq_plan.effective_splits, 2);
    assert!((seq_plan.occupancy - 2.0 / 132.0).abs() < 1e-12);

    // The inequality the checker proves over the whole domain, at its
    // motivating point: strictly better, never worse.
    assert!(seq_plan.occupancy > std_plan.occupancy);
}

#[test]
fn end_to_end_run_reports_domain_size() {
    // analysis::run with the quick domain: the JSON artifact carries the
    // enumerated domain size alongside zero violations.
    let mut opts = LintOptions::at_repo_root(&repo_root());
    opts.modelcheck = Some(ModelCheckConfig::quick());
    let report = analysis::run(&opts).expect("lint run");
    assert!(report.clean(), "{:#?}", report.findings);
    let mc = report.modelcheck.as_ref().expect("model-check summary");
    let json = mc.to_string_pretty();
    assert!(json.contains("no_regression_domain"));
    assert!(json.contains("\"violations\": 0"));
    let full = report.to_json().to_string_pretty();
    assert!(full.contains("\"errors\": 0"));
}
