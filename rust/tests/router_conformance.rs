//! Router conformance: one shared invariant harness run over every
//! routing policy the fleet can mount (`round-robin`, `least-loaded`,
//! `session-affinity`, `disaggregated`).
//!
//! The fleet asserts these contracts at runtime (`check_route_contract`);
//! this suite proves them *of the policies themselves*, over randomized
//! snapshot sets, so a new router cannot land without inheriting the
//! obligations:
//!
//! 1. a router never returns a replica whose `can_ever_admit` is false —
//!    and when nobody qualifies it refuses with a typed
//!    [`RouteError::Unroutable`] naming the request and its demand,
//! 2. refusal reasons are actionable: pin refusals name the pinned
//!    replica, cross-pool refusals say "outside this candidate pool",
//! 3. equal state + equal inputs = equal decisions (fleet replays are
//!    byte-reproducible),
//! 4. routers speak **global** [`ReplicaSnapshot::index`] values, never
//!    slice positions — a disaggregated fleet routes over pool subsets
//!    like `[3, 5, 9]`,
//! 5. the prefix-affinity bonus is bounded: it steers between equally
//!    loaded replicas but never outweighs a whole queued request.

use fa3_split::cluster::router::{self, Disaggregated, ReplicaSnapshot, RouteError, Router};
use fa3_split::coordinator::Request;
use fa3_split::util::prng::Rng;
use fa3_split::util::proptest_lite::{check, Domain};

/// All mountable policies, fresh. The closure form lets properties build
/// as many independent instances of the same policy as they need.
fn fresh_routers() -> Vec<(&'static str, Box<dyn Fn() -> Box<dyn Router>>)> {
    let mut out: Vec<(&'static str, Box<dyn Fn() -> Box<dyn Router>>)> = Vec::new();
    for name in router::ROUTER_NAMES {
        out.push((name, Box::new(move || router::by_name(name).expect("registered"))));
    }
    out
}

fn snap(index: usize, queue: usize, running: usize, free: usize) -> ReplicaSnapshot {
    ReplicaSnapshot {
        index,
        queue_depth: queue,
        running,
        free_blocks: free,
        total_blocks: 100,
        can_admit_now: free > 0,
        can_ever_admit: true,
        shared_blocks: 0,
        demand_blocks: 6,
    }
}

fn req(id: u64) -> Request {
    Request::new(id, vec![1; 64], 32)
}

/// Randomized snapshot set: `n` replicas at stride-2 global indices
/// starting at `base`, eligibility from the low bits of `mask`, load
/// fields from a seeded Rng.
fn random_pool(n: usize, base: usize, mask: u64, seed: u64) -> Vec<ReplicaSnapshot> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let mut s = snap(
                base + 2 * i,
                rng.below(5) as usize,
                rng.below(4) as usize,
                rng.below(101) as usize,
            );
            s.can_ever_admit = mask & (1 << i) != 0;
            s.shared_blocks = rng.below(7) as usize;
            s
        })
        .collect()
}

// ---------------------------------------------------------------------
// 1. Eligibility: never route to a guaranteed refusal; refuse loudly
//    when nobody qualifies.
// ---------------------------------------------------------------------

#[test]
fn no_router_ever_picks_a_never_admit_replica() {
    check(
        "router-eligibility",
        &[
            Domain::new(1, 5),   // pool size
            Domain::new(0, 31),  // eligibility mask
            Domain::new(0, 9),   // base of the global-index range
            Domain::new(0, 999), // load-field seed
        ],
        |case| {
            let (n, mask, base) = (case[0] as usize, case[1], case[2] as usize);
            let pool = random_pool(n, base, mask, case[3]);
            let any_eligible = pool.iter().any(|s| s.can_ever_admit);
            for (name, fresh) in fresh_routers() {
                let mut r = fresh();
                // Distinct sessions per turn: stickiness stays out of the
                // eligibility question.
                for turn in 0..3u64 {
                    match r.route(&req(turn), 1000 + turn, &pool) {
                        Ok(idx) => {
                            let s = pool.iter().find(|s| s.index == idx).ok_or(format!(
                                "{name} returned {idx}, not a member of the pool"
                            ))?;
                            if !s.can_ever_admit {
                                return Err(format!(
                                    "{name} routed to replica {idx} which can never admit"
                                ));
                            }
                        }
                        Err(RouteError::Unroutable { request, reason }) => {
                            if any_eligible {
                                return Err(format!(
                                    "{name} refused request {request} with an eligible \
                                     replica present: {reason}"
                                ));
                            }
                        }
                        Err(e) => return Err(format!("{name} failed unexpectedly: {e}")),
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn refusals_name_the_request_and_its_demand() {
    // Nobody can ever admit: every policy must refuse with the typed
    // error carrying the request id and the token demand (96 = 64 + 32).
    let mut pool = vec![snap(0, 0, 0, 100), snap(1, 0, 0, 100)];
    for s in &mut pool {
        s.can_ever_admit = false;
    }
    for (name, fresh) in fresh_routers() {
        let mut r = fresh();
        let err = r.route(&req(7), 7, &pool).unwrap_err();
        match &err {
            RouteError::Unroutable { request: 7, reason } => {
                assert!(reason.contains("96 tokens"), "{name}: uninformative reason {reason:?}");
            }
            other => panic!("{name}: expected Unroutable for request 7, got {other:?}"),
        }
        // An empty slice is the distinct NoReplicas error, not a panic.
        assert_eq!(r.route(&req(8), 8, &[]).unwrap_err(), RouteError::NoReplicas, "{name}");
    }
}

// ---------------------------------------------------------------------
// 2. Determinism: two fresh instances fed the same call sequence make
//    the same decisions, errors included.
// ---------------------------------------------------------------------

#[test]
fn equal_state_and_inputs_give_equal_decisions() {
    check(
        "router-determinism",
        &[Domain::new(1, 5), Domain::new(0, 31), Domain::new(0, 999)],
        |case| {
            let (n, mask, seed) = (case[0] as usize, case[1], case[2]);
            for (name, fresh) in fresh_routers() {
                let (mut a, mut b) = (fresh(), fresh());
                let mut rng = Rng::new(seed);
                for turn in 0..8u64 {
                    // Sessions drawn from a small space so sticky routers
                    // exercise both pin hits and first-turn placement.
                    let session = rng.below(3);
                    let pool = random_pool(n, 0, mask, seed ^ turn);
                    let da = a.route(&req(turn), session, &pool);
                    let db = b.route(&req(turn), session, &pool);
                    if da != db {
                        return Err(format!(
                            "{name} diverged on turn {turn}: {da:?} vs {db:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// 3. Global-index contract: pool subsets route by member index, and a
//    sticky pin resolves by index — or refuses when shown another pool.
// ---------------------------------------------------------------------

#[test]
fn routers_speak_global_indices_over_pool_subsets() {
    check(
        "router-global-index",
        &[Domain::new(0, 20), Domain::new(1, 4), Domain::new(0, 999)],
        |case| {
            let (base, n, seed) = (case[0] as usize, case[1] as usize, case[2]);
            let pool = random_pool(n, base, u64::MAX, seed);
            let members: Vec<usize> = pool.iter().map(|s| s.index).collect();
            for (name, fresh) in fresh_routers() {
                let mut r = fresh();
                for turn in 0..2 * n as u64 {
                    let idx = r
                        .route(&req(turn), turn % 2, &pool)
                        .map_err(|e| format!("{name}: {e}"))?;
                    if !members.contains(&idx) {
                        return Err(format!(
                            "{name} returned {idx}; pool members are {members:?} \
                             (slice-position arithmetic?)"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sticky_policies_keep_pins_pool_scoped() {
    // Both sticky policies (session-affinity, and disaggregated's decode
    // stage) pin in one pool, then refuse — rather than re-pin — when the
    // same session is presented a disjoint pool.
    let decode_pool = vec![snap(4, 0, 0, 100), snap(6, 0, 0, 100)];
    let other_pool = vec![snap(0, 0, 0, 100), snap(1, 0, 0, 100)];
    for name in ["session-affinity", "disaggregated"] {
        let mut r = router::by_name(name).expect("registered");
        let first = r.route(&req(0), 77, &decode_pool).unwrap();
        assert!([4, 6].contains(&first), "{name}");
        let err = r.route(&req(1), 77, &other_pool).unwrap_err();
        assert!(
            err.to_string().contains("outside this candidate pool"),
            "{name}: wrong refusal {err}"
        );
        // The pin survives the refusal: back home, the session lands on
        // the same replica as before.
        assert_eq!(r.route(&req(2), 77, &decode_pool).unwrap(), first, "{name}");
    }
}

// ---------------------------------------------------------------------
// 4. Bounded prefix bonus: affinity steers ties, never jumps queues.
// ---------------------------------------------------------------------

#[test]
fn prefix_bonus_never_outweighs_a_queued_request() {
    check(
        "router-prefix-bounded",
        &[Domain::new(1, 6), Domain::new(0, 6), Domain::new(0, 100)],
        |case| {
            let (queue, shared, free) = (case[0] as usize, case[1] as usize, case[2] as usize);
            // Replica 5: idle, cold cache. Replica 9: >= 1 queued request
            // ahead, up to a full prefix hit (demand_blocks = 6). Equal KV
            // pressure. The load-aware policies must pick the idle replica:
            // hit ratio <= 1 < queue + running gap.
            let idle = snap(5, 0, 0, free);
            let mut warm = snap(9, queue, 0, free);
            warm.shared_blocks = shared;
            let pool = vec![idle, warm];
            for name in ["least-loaded", "session-affinity", "disaggregated"] {
                let mut r = router::by_name(name).expect("registered");
                let idx = r.route(&req(0), 0, &pool).map_err(|e| format!("{name}: {e}"))?;
                if idx != 5 {
                    return Err(format!(
                        "{name} jumped a {queue}-deep queue for a {shared}/6 prefix hit"
                    ));
                }
            }
            // The disaggregated prefill stage is load/prefix-aware too.
            let mut d = Disaggregated::new();
            let idx = d.route_prefill(&req(0), 0, &pool).map_err(|e| e.to_string())?;
            if idx != 5 {
                return Err("prefill stage jumped the queue for a prefix hit".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// 5. Stage independence: the two-stage router's prefill placement never
//    creates decode pins, and only it advertises two stages.
// ---------------------------------------------------------------------

#[test]
fn only_the_disaggregated_router_is_two_stage() {
    for (name, fresh) in fresh_routers() {
        let mut r = fresh();
        assert_eq!(r.two_stage().is_some(), name == "disaggregated", "{name}");
    }
}

#[test]
fn prefill_placement_never_pins_the_decode_stage() {
    check(
        "router-stage-independence",
        &[Domain::new(1, 4), Domain::new(0, 999)],
        |case| {
            let (n, seed) = (case[0] as usize, case[1]);
            let prefill_pool = random_pool(n, 0, u64::MAX, seed);
            let decode_pool = random_pool(n, 10, u64::MAX, seed ^ 1);
            let mut d = Disaggregated::new();
            for turn in 0..4u64 {
                d.route_prefill(&req(turn), turn, &prefill_pool).map_err(|e| e.to_string())?;
                if d.decode_pin_of(turn).is_some() {
                    return Err(format!("prefill placement pinned session {turn}"));
                }
                let idx = d.route(&req(turn), turn, &decode_pool).map_err(|e| e.to_string())?;
                if d.decode_pin_of(turn) != Some(idx) {
                    return Err(format!("decode placement failed to pin session {turn}"));
                }
                if prefill_pool.iter().any(|s| s.index == idx) {
                    return Err(format!("decode pin {idx} landed in the prefill pool"));
                }
            }
            Ok(())
        },
    );
}
