//! Coordinator integration + property tests: block accounting under random
//! op sequences, end-to-end completion under random workloads (simulated
//! backend), FCFS fairness, and failure injection.

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{
    BlockManager, BlockManagerConfig, Engine, EngineConfig, FinishReason, Request, SubmitError,
};
use fa3_split::planner::Planner;
use fa3_split::util::prng::Rng;
use fa3_split::util::proptest_lite::{check, Domain};
use fa3_split::workload::ChatWorkload;

fn sim_engine(policy_patched: bool, max_batch: usize) -> Engine {
    let buckets: Vec<usize> = [1, 2, 4, 8].into_iter().filter(|&b| b <= max_batch).collect();
    let max_batch = *buckets.last().unwrap(); // largest bucket IS the cap
    Engine::builder(Box::new(SimBackend::h100()))
        .planner(if policy_patched { Planner::sequence_aware() } else { Planner::standard() })
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(EngineConfig {
            batcher: fa3_split::coordinator::BatcherConfig { max_batch, batch_buckets: buckets },
            ..Default::default()
        })
        .build()
        .unwrap()
}

#[test]
fn block_manager_random_ops_preserve_invariants() {
    // Random interleavings of admit/release: accounting must always
    // balance and frees must never exceed the budget.
    check(
        "block-ops",
        &[Domain::new(1, 64), Domain::new(1, 6), Domain::new(0, u64::MAX)],
        |case| {
            let num_blocks = case[0] as usize * 4;
            let block_size = 1 << case[1];
            let mut rng = Rng::new(case[2]);
            let mut mgr = BlockManager::new(BlockManagerConfig {
                block_size,
                num_blocks,
                max_seq: block_size * num_blocks,
                ..Default::default()
            });
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..200 {
                if live.is_empty() || rng.chance(0.6) {
                    let prompt_len = rng.range(1, block_size * 4);
                    let max_new = rng.range(0, block_size * 2);
                    // Half the prompts repeat content (prefix sharing
                    // engages — tag-0 prompts are prefixes of each
                    // other, so full-block AND tail matches occur),
                    // half are unique. The paired predicate is the
                    // sharing-aware `can_admit_prompt`: the blind
                    // `can_admit` cannot promise admission when a COW
                    // tail donor must also be attached (transient
                    // footprint is blocks_for(total) + 1).
                    let tag = if rng.chance(0.5) { 0 } else { next_id as i32 + 1 };
                    let prompt: Vec<i32> =
                        (0..prompt_len).map(|i| tag * 100_000 + i as i32).collect();
                    if mgr.can_admit_prompt(&prompt, max_new) {
                        mgr.admit(next_id, &prompt, max_new)
                            .map_err(|e| format!("admit after can_admit_prompt: {e}"))?;
                        live.push(next_id);
                        next_id += 1;
                    }
                } else {
                    let idx = rng.range(0, live.len() - 1);
                    let id = live.swap_remove(idx);
                    mgr.release(id).map_err(|e| format!("release: {e}"))?;
                }
                mgr.check_invariants().map_err(|e| format!("{e}"))?;
                if mgr.free_blocks() > num_blocks {
                    return Err("free blocks exceed budget".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn random_workloads_always_complete() {
    // Any random chat workload must fully drain with every request
    // accounted for exactly once.
    check(
        "workload-completion",
        &[Domain::new(1, 24), Domain::new(1, 8), Domain::new(0, u64::MAX)],
        |case| {
            let n_requests = case[0] as usize;
            let max_batch = case[1] as usize;
            let workload = ChatWorkload {
                seed: case[2],
                n_requests,
                prompt_median: 100,
                output_mean: 12,
                output_cap: 32,
                ..Default::default()
            };
            let mut engine = sim_engine(true, max_batch);
            for g in workload.generate() {
                engine.submit(g.request).map_err(|e| format!("refused: {e}"))?;
            }
            let done = engine.run_until_idle().map_err(|e| format!("{e:#}"))?;
            if done.len() != n_requests {
                return Err(format!("{} of {n_requests} finished", done.len()));
            }
            let mut ids: Vec<u64> = done.iter().map(|f| f.id).collect();
            ids.sort_unstable();
            ids.dedup();
            if ids.len() != n_requests {
                return Err("duplicate/missing request ids".into());
            }
            for f in &done {
                if f.reason != FinishReason::Length {
                    return Err(format!("req {} finished with {:?}", f.id, f.reason));
                }
                if f.tokens.is_empty() {
                    return Err(format!("req {} generated nothing", f.id));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fcfs_scheduling_order() {
    // With a single slot, completion order must equal submission order.
    let mut engine = sim_engine(false, 1);
    for id in 0..6 {
        engine.submit(Request::new(id, vec![1; 20], 4)).unwrap();
    }
    let done = engine.run_until_idle().unwrap();
    let order: Vec<u64> = done.iter().map(|f| f.id).collect();
    assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
}

#[test]
fn oversized_request_rejected_not_stuck() {
    // A request that can never fit is refused at submission with an
    // explicit outcome (the seed let it wedge the FCFS queue head forever;
    // the admission controller rejects it up front), and the engine stays
    // serviceable for everything behind it.
    let mut engine = sim_engine(true, 2);
    // max_seq is 1024: this can never be admitted.
    let err = engine.submit(Request::new(0, vec![1; 1000], 500)).unwrap_err();
    assert!(matches!(err, SubmitError::Unschedulable { .. }));
    engine.submit(Request::new(1, vec![1; 10], 4)).unwrap();
    let done = engine.run_until_idle().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 1);
    assert_eq!(done[0].reason, FinishReason::Length);
    assert_eq!(engine.metrics.rejected_unschedulable, 1);
    assert!(engine.is_idle());
}

#[test]
fn policy_choice_changes_only_latency_not_results() {
    // In simulated mode the token stream is synthetic but deterministic:
    // both policies must produce identical token sequences and counts —
    // the policy only moves time.
    let workload = ChatWorkload { n_requests: 6, seed: 99, ..Default::default() };
    let run = |patched: bool| {
        let mut e = sim_engine(patched, 4);
        for g in workload.generate() {
            e.submit(g.request).unwrap();
        }
        let mut done = e.run_until_idle().unwrap();
        done.sort_by_key(|f| f.id);
        (
            done.iter().map(|f| f.tokens.clone()).collect::<Vec<_>>(),
            e.metrics.tokens_generated,
        )
    };
    let (tok_std, n_std) = run(false);
    let (tok_pat, n_pat) = run(true);
    assert_eq!(tok_std, tok_pat);
    assert_eq!(n_std, n_pat);
}

#[test]
fn metrics_are_internally_consistent() {
    let mut engine = sim_engine(true, 4);
    let workload = ChatWorkload { n_requests: 10, seed: 5, output_mean: 16, output_cap: 16, ..Default::default() };
    for g in workload.generate() {
        engine.submit(g.request).unwrap();
    }
    let done = engine.run_until_idle().unwrap();
    let m = &engine.metrics;
    assert_eq!(m.requests_finished, done.len());
    let total_tokens: usize = done.iter().map(|f| f.tokens.len()).sum();
    assert_eq!(m.tokens_generated, total_tokens);
    assert!(m.decode_steps <= m.steps);
    assert!(m.prefill_calls >= 10);
    // Split histogram counts one entry per decode scheduling decision.
    let hist_total: usize = m.split_histogram.iter().sum();
    assert_eq!(hist_total, m.decode_steps);
    for f in &done {
        assert!(f.timing.finished_us >= f.timing.first_token_us);
        assert!(f.timing.first_token_us >= f.timing.arrival_us);
    }
}
