//! Flight-recorder determinism and non-interference (DESIGN.md
//! §Observability).
//!
//! Three contracts, each load-bearing for using traces as evidence:
//!
//! 1. **Determinism** — the engine runs on a virtual clock, so the same
//!    seed must produce a byte-identical Chrome trace across runs. A
//!    trace that varies between identical runs cannot be diffed, cached,
//!    or attached to a bug report as ground truth.
//! 2. **Non-interference** — recording is observation, not perturbation:
//!    the token streams and timing of a traced run must be byte-identical
//!    to the same run with the recorder disabled.
//! 3. **Span fidelity** — TTFT/TPOT reconstructed from the event ring
//!    must equal `RequestTiming`'s to the microsecond for every finished
//!    request (the ISSUE's acceptance criterion).

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{Engine, EngineConfig, FinishedRequest};
use fa3_split::obs::{self, reconstruct, RequestSpan};
use fa3_split::planner::Planner;
use fa3_split::util::json::Json;
use fa3_split::workload::ChatWorkload;

fn run(seed: u64, trace_capacity: usize) -> (Engine, Vec<FinishedRequest>) {
    let mut engine = Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(EngineConfig { trace_capacity, ..Default::default() })
        .build()
        .unwrap();
    let workload = ChatWorkload {
        seed,
        n_requests: 8,
        prompt_median: 200,
        output_mean: 24,
        output_cap: 48,
        mean_gap_us: 400,
        ..Default::default()
    };
    for g in workload.generate() {
        engine.submit_at(g.request, g.arrival_offset_us).unwrap();
    }
    let done = engine.run_until_idle().unwrap();
    (engine, done)
}

/// The run's externally visible result: every token of every request plus
/// its timing, in request order.
fn token_snapshot(done: &[FinishedRequest]) -> String {
    let mut rows: Vec<String> = done
        .iter()
        .map(|f| {
            format!(
                "{}:{:?}:{:?}:{}:{}",
                f.id,
                f.reason,
                f.tokens,
                f.timing.ttft_us(),
                f.timing.finished_us
            )
        })
        .collect();
    rows.sort();
    rows.join("\n")
}

#[test]
fn same_seed_same_bytes() {
    let (a, _) = run(0x7AC3, 4096);
    let (b, _) = run(0x7AC3, 4096);
    let ta = obs::engine_trace(a.recorder(), "engine").to_string();
    let tb = obs::engine_trace(b.recorder(), "engine").to_string();
    assert!(!ta.is_empty() && a.recorder().len() > 0);
    assert_eq!(ta, tb, "identical seeds must serialize identical traces");
    // A different seed is a different run, and the trace shows it.
    let (c, _) = run(0xBEEF, 4096);
    assert_ne!(ta, obs::engine_trace(c.recorder(), "engine").to_string());
}

#[test]
fn tracing_does_not_perturb_the_run() {
    let (traced_engine, traced) = run(0x51DE, 4096);
    let (untraced_engine, untraced) = run(0x51DE, 0);
    assert!(traced_engine.recorder().enabled());
    assert!(!untraced_engine.recorder().enabled());
    assert!(untraced_engine.recorder().len() == 0);
    assert_eq!(
        token_snapshot(&traced),
        token_snapshot(&untraced),
        "recording must be pure observation: tokens and timings identical"
    );
    assert_eq!(traced_engine.now_us(), untraced_engine.now_us());
}

#[test]
fn spans_agree_with_engine_timing_to_the_microsecond() {
    let (engine, done) = run(0x0B51, 65536);
    assert!(!done.is_empty());
    let spans: Vec<RequestSpan> = reconstruct(engine.recorder().events());
    for f in &done {
        let span = spans
            .iter()
            .find(|s| s.request == f.id)
            .unwrap_or_else(|| panic!("request {} missing from the trace", f.id));
        assert!(span.finished(), "request {} should have a Finished event", f.id);
        assert_eq!(
            span.ttft_us(),
            Some(f.timing.ttft_us()),
            "span TTFT must equal RequestTiming TTFT for request {}",
            f.id
        );
        let span_tpot = span.tpot_us().unwrap();
        assert!(
            (span_tpot - f.timing.tpot_us()).abs() < 1e-9,
            "span TPOT {span_tpot} != timing TPOT {} for request {}",
            f.timing.tpot_us(),
            f.id
        );
        assert_eq!(span.n_generated as usize, f.timing.n_generated);
    }
}

#[test]
fn chrome_trace_is_schema_valid_json() {
    let (engine, _) = run(0xCAFE, 4096);
    let s = obs::engine_trace(engine.recorder(), "engine").to_string();
    let parsed = Json::parse(&s).expect("exported trace must be valid JSON");
    let Json::Obj(top) = &parsed else { panic!("top level must be an object") };
    let Some(Json::Arr(events)) = top.get("traceEvents") else {
        panic!("traceEvents array required")
    };
    assert!(!events.is_empty());
    for ev in events {
        let Json::Obj(e) = ev else { panic!("each trace event must be an object") };
        for key in ["ph", "pid", "tid"] {
            assert!(e.contains_key(key), "trace event missing '{key}': {ev:?}");
        }
    }
}
