//! Disaggregation differential + property suite.
//!
//! Two identity theorems and one conservation law pin the handoff
//! machinery down:
//!
//! * **Collapsed pools** — the two-stage router mounted on a *colocated*
//!   topology must be byte-identical to plain session-affinity: same
//!   placements, same token streams, same timings. The disaggregated
//!   code path must be strictly additive.
//! * **Zero-cost link** — a 1-prefill + 1-decode split over the free
//!   interconnect must serve byte-identical token streams to a colocated
//!   single replica: position-pure synthetic tokens make the prefill leg
//!   + continuation concatenation equal the uninterrupted stream, so any
//!   divergence is a real handoff bug (wrong continuation prompt, lost
//!   first token, off-by-one in `max_new`).
//! * **Ledger conservation** — `begun == delivered + cancelled +
//!   in_flight`, counts and blocks, under random admit/handoff/cancel
//!   interleavings, with failed closures leaving the books untouched.
//!
//! Plus the fleet-level regressions: churn (decode refusals) cancels
//! handoffs without leaking, and decode pins never migrate across pools.

use std::collections::HashMap;

use fa3_split::backend::AttnGeometry;
use fa3_split::cluster::{
    router, ClusterTopology, Fleet, FleetConfig, FleetReport, Interconnect, ReplicaRole, Router,
    Transfer, TransferLedger, TpConfig,
};
use fa3_split::coordinator::{
    BatcherConfig, BlockManagerConfig, EngineConfig, Priority, Request,
};
use fa3_split::planner::DeviceProfile;
use fa3_split::util::prng::Rng;
use fa3_split::util::proptest_lite::{check, Domain};
use fa3_split::workload::{ChatWorkload, GeneratedRequest};

fn llama70b() -> AttnGeometry {
    AttnGeometry { h_q: 64, h_kv: 8, d: 128, max_seq: 1024 }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig { batcher: BatcherConfig::for_max_batch(4), ..Default::default() }
}

fn colocated(n: usize) -> ClusterTopology {
    ClusterTopology::builder(llama70b())
        .tp(TpConfig::new(8))
        .replicas(n, DeviceProfile::H100_SXM)
        .build()
        .unwrap()
}

fn split(prefill: usize, decode: usize, link: Interconnect) -> ClusterTopology {
    ClusterTopology::builder(llama70b())
        .tp(TpConfig::new(8))
        .pool(prefill, DeviceProfile::H100_SXM, ReplicaRole::Prefill)
        .pool(decode, DeviceProfile::H100_SXM, ReplicaRole::Decode)
        .interconnect(link)
        .build()
        .unwrap()
}

fn run_fleet(
    topology: ClusterTopology,
    router: Box<dyn Router>,
    engine: EngineConfig,
    stream: &[GeneratedRequest],
) -> FleetReport {
    let mut fleet =
        Fleet::new(topology, router, FleetConfig::default().policy("sequence-aware").engine(engine))
            .unwrap();
    fleet.run(stream).unwrap()
}

fn heavy_decode(seed: u64, n: usize) -> ChatWorkload {
    ChatWorkload::boundary_bucket(seed, n, 48)
}

/// `(id, reason, tokens)` per finished request, sorted — the
/// stream-identity signature (timings deliberately excluded where only
/// streams must match).
fn streams(report: &FleetReport) -> Vec<(u64, String, Vec<i32>)> {
    let mut sig: Vec<(u64, String, Vec<i32>)> = report
        .finished
        .iter()
        .map(|f| (f.id, format!("{:?}", f.reason), f.tokens.clone()))
        .collect();
    sig.sort();
    sig
}

// ---------------------------------------------------------------------
// Collapsed pools: two-stage router on a colocated topology ==
// session-affinity, byte for byte.
// ---------------------------------------------------------------------

#[test]
fn collapsed_pools_are_byte_identical_to_session_affinity() {
    for seed in [0x1D, 0x2D, 0x3D] {
        let workload =
            ChatWorkload { turns_per_session: 2, mean_gap_us: 300, ..heavy_decode(seed, 12) };
        let stream = workload.generate();
        let affinity = run_fleet(
            colocated(2),
            Box::new(router::SessionAffinity::new()),
            engine_cfg(),
            &stream,
        );
        let collapsed = run_fleet(
            colocated(2),
            Box::new(router::Disaggregated::new()),
            engine_cfg(),
            &stream,
        );

        assert_eq!(affinity.assignments, collapsed.assignments, "seed {seed:#x}: placement");
        assert!(collapsed.prefill_assignments.is_empty(), "no prefill legs when colocated");
        assert_eq!((collapsed.handoffs, collapsed.handoffs_cancelled), (0, 0));
        assert_eq!(collapsed.transferred_blocks, 0);
        assert_eq!(streams(&affinity), streams(&collapsed), "seed {seed:#x}: streams");
        // Identical placement + identical engines => identical timings.
        let timing = |r: &FleetReport| {
            let mut t: Vec<(u64, u64, u64, u64)> = r
                .finished
                .iter()
                .map(|f| {
                    (f.id, f.timing.scheduled_us, f.timing.first_token_us, f.timing.finished_us)
                })
                .collect();
            t.sort();
            t
        };
        assert_eq!(timing(&affinity), timing(&collapsed), "seed {seed:#x}: timings");
        assert_eq!(affinity.rejected, collapsed.rejected, "seed {seed:#x}");
    }
}

// ---------------------------------------------------------------------
// Zero-cost link: split serving is stream-invisible.
// ---------------------------------------------------------------------

#[test]
fn zero_cost_split_streams_match_colocated_byte_for_byte() {
    for seed in [0xA1, 0xB2] {
        let workload = ChatWorkload { mean_gap_us: 500, ..heavy_decode(seed, 10) };
        let stream = workload.generate();
        let coloc = run_fleet(
            colocated(1),
            Box::new(router::RoundRobin::new()),
            engine_cfg(),
            &stream,
        );
        let zero = run_fleet(
            split(1, 1, Interconnect::ZERO),
            Box::new(router::Disaggregated::new()),
            engine_cfg(),
            &stream,
        );

        assert_eq!(coloc.finished.len(), zero.finished.len(), "seed {seed:#x}");
        assert_eq!(zero.rejected, 0, "seed {seed:#x}");
        assert_eq!(streams(&coloc), streams(&zero), "seed {seed:#x}: streams diverged");
        // The free link still moves blocks — it just charges nothing.
        assert!(zero.handoffs > 0, "seed {seed:#x}");
        assert_eq!(zero.transfer_wire_us, 0, "seed {seed:#x}: zero link charged wire time");
        // Every generated token count survives the split exactly.
        let total = |r: &FleetReport| -> usize {
            r.finished.iter().map(|f| f.tokens.len()).sum()
        };
        assert_eq!(total(&coloc), total(&zero), "seed {seed:#x}");
    }
}

// ---------------------------------------------------------------------
// Ledger conservation under random interleavings.
// ---------------------------------------------------------------------

#[test]
fn ledger_conservation_survives_random_interleavings() {
    check(
        "ledger-conservation",
        &[Domain::new(1, 60), Domain::new(0, u64::MAX / 2), Domain::new(2, 9)],
        |case| {
            let (n_ops, seed, id_space) = (case[0], case[1], case[2]);
            let mut rng = Rng::new(seed);
            let mut ledger = TransferLedger::new();
            // Shadow model: the set of ids we believe are in flight.
            let mut open: Vec<u64> = Vec::new();
            for step in 0..n_ops {
                let id = rng.below(id_space);
                let blocks = 1 + rng.below(40) as usize;
                let t = Transfer {
                    request: id,
                    from: 0,
                    blocks,
                    depart_us: 10 * step,
                    arrive_us: 10 * step + rng.below(500),
                };
                let before =
                    (ledger.begun(), ledger.delivered(), ledger.cancelled(), ledger.in_flight());
                let mut refused = false;
                match rng.below(3) {
                    0 => {
                        let res = ledger.begin(t);
                        if open.contains(&id) {
                            if res.is_ok() {
                                return Err(format!("double begin for {id} accepted"));
                            }
                            refused = true;
                        } else {
                            res.map_err(|e| format!("begin({id}) refused: {e}"))?;
                            open.push(id);
                        }
                    }
                    1 => {
                        let res = ledger.deliver(id);
                        if open.contains(&id) {
                            let got =
                                res.map_err(|e| format!("deliver({id}) refused: {e}"))?;
                            if got.request != id {
                                return Err("deliver returned the wrong transfer".into());
                            }
                            open.retain(|&x| x != id);
                        } else {
                            if res.is_ok() {
                                return Err(format!(
                                    "double-free: deliver({id}) with nothing open"
                                ));
                            }
                            refused = true;
                        }
                    }
                    _ => {
                        let res = ledger.cancel(id);
                        if open.contains(&id) {
                            res.map_err(|e| format!("cancel({id}) refused: {e}"))?;
                            open.retain(|&x| x != id);
                        } else {
                            if res.is_ok() {
                                return Err(format!(
                                    "double-free: cancel({id}) with nothing open"
                                ));
                            }
                            refused = true;
                        }
                    }
                }
                // Conservation must hold after every single op, and a
                // refused op must leave the books exactly as they were.
                ledger.check_invariants().map_err(|e| format!("after op {step}: {e}"))?;
                let after =
                    (ledger.begun(), ledger.delivered(), ledger.cancelled(), ledger.in_flight());
                if refused && before != after {
                    return Err(format!(
                        "refused op mutated the books: {before:?} -> {after:?}"
                    ));
                }
                if open.len() != ledger.in_flight() {
                    return Err(format!(
                        "in-flight drifted from the model: {} vs {}",
                        ledger.in_flight(),
                        open.len()
                    ));
                }
            }
            // Full drain: close everything both ways, alternating.
            for (i, id) in open.drain(..).enumerate() {
                if i % 2 == 0 {
                    ledger.deliver(id).map_err(|e| format!("drain deliver: {e}"))?;
                } else {
                    ledger.cancel(id).map_err(|e| format!("drain cancel: {e}"))?;
                }
            }
            ledger.check_invariants().map_err(|e| format!("after drain: {e}"))?;
            if !ledger.drained() {
                return Err("ledger not drained after closing every open transfer".into());
            }
            if ledger.begun() != ledger.delivered() + ledger.cancelled() {
                return Err("drained ledger does not balance".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Fleet churn: decode refusals cancel their transfers, books balance.
// ---------------------------------------------------------------------

/// A hand-built arrival stream: `normal` requests that fit everywhere
/// interleaved with `oversized` ones whose *continuation* (prompt +
/// max_new) exceeds the KV budget while the prefill leg (prompt + 1)
/// still fits — the shape that forces a decode-side refusal after a
/// successful prefill, i.e. the cancel path.
fn churn_stream() -> Vec<GeneratedRequest> {
    let mut out = Vec::new();
    for i in 0..12u64 {
        let oversized = i % 3 == 2;
        let (prompt_len, max_new) = if oversized { (150, 60) } else { (64, 8) };
        let prompt: Vec<i32> = (0..prompt_len).map(|p| (p % 1000) as i32).collect();
        out.push(GeneratedRequest {
            request: Request::new(i, prompt, max_new),
            arrival_offset_us: 50 * i,
            priority: Priority::Standard,
            session: i,
            turn: 0,
        });
    }
    out
}

#[test]
fn decode_refusals_cancel_their_transfers_without_leaking() {
    // 12 blocks x 16 tokens = 192-token budget: the oversized requests
    // (150 + 60 = 210) can never decode, but their prefill leg (151) fits.
    let engine = EngineConfig {
        blocks: BlockManagerConfig {
            block_size: 16,
            num_blocks: 12,
            max_seq: 1024,
            enable_prefix_sharing: true,
        },
        ..engine_cfg()
    };
    let stream = churn_stream();
    let n_oversized = stream.iter().filter(|g| g.request.max_new_tokens == 60).count();
    let topology = split(1, 1, Interconnect::PCIE);
    let mut fleet = Fleet::new(
        topology,
        Box::new(router::Disaggregated::new()),
        FleetConfig::default().policy("sequence-aware").engine(engine),
    )
    .unwrap();
    let report = fleet.run(&stream).unwrap();

    assert_eq!(report.finished.len() + report.rejected, stream.len(), "requests lost");
    assert_eq!(report.rejected, n_oversized, "exactly the oversized requests bounce");
    assert_eq!(report.handoffs_cancelled, n_oversized, "each bounce cancels its transfer");
    assert_eq!(report.handoffs, stream.len() - n_oversized, "the rest deliver");
    // Cancelled wire time still accrues (the blocks crossed before the
    // refusal), and the ledger must balance to the block.
    assert!(report.transfer_wire_us > 0);
    fleet.ledger().check_invariants().unwrap();
    assert!(fleet.ledger().drained(), "transfers left on the wire after a full run");
    assert_eq!(
        fleet.ledger().begun(),
        fleet.ledger().delivered() + fleet.ledger().cancelled()
    );
}

// ---------------------------------------------------------------------
// Cross-pool pin regression: decode stickiness never migrates.
// ---------------------------------------------------------------------

#[test]
fn decode_pins_stay_in_the_decode_pool_across_turns() {
    let workload = ChatWorkload {
        turns_per_session: 3,
        mean_gap_us: 400,
        ..heavy_decode(0x5E55, 18)
    };
    let topology = split(1, 2, Interconnect::NVLINK);
    let prefill_pool = topology.pool(ReplicaRole::Prefill);
    let decode_pool = topology.pool(ReplicaRole::Decode);
    let mut fleet = Fleet::new(
        topology,
        Box::new(router::Disaggregated::new()),
        FleetConfig::default().policy("sequence-aware").engine(engine_cfg()),
    )
    .unwrap();
    let report = fleet.run(&workload.generate()).unwrap();

    assert_eq!(report.rejected, 0);
    // Prefill legs only ever land in the prefill pool...
    for a in &report.prefill_assignments {
        assert!(prefill_pool.contains(&a.replica), "prefill leg on replica {}", a.replica);
    }
    // ...decode legs only in the decode pool, and a session's decode
    // replica never changes once pinned.
    let mut pin: HashMap<u64, usize> = HashMap::new();
    for a in &report.assignments {
        assert!(decode_pool.contains(&a.replica), "decode leg on replica {}", a.replica);
        let home = *pin.entry(a.session).or_insert(a.replica);
        assert_eq!(home, a.replica, "session {} migrated decode replicas", a.session);
    }
    assert!(report.handoffs > 0);
    assert_eq!(report.pool(ReplicaRole::Decode).len(), 2);
    assert_eq!(report.pool(ReplicaRole::Prefill).len(), 1);
}

// ---------------------------------------------------------------------
// CLI flag validation: unknown --roles / --xfer values exit 2 with the
// known names listed (same contract as every other enumerated flag).
// ---------------------------------------------------------------------

#[test]
fn cli_rejects_unknown_roles_and_xfer_values() {
    let bin = env!("CARGO_BIN_EXE_fa3-split");
    let run = |args: &[&str]| {
        std::process::Command::new(bin)
            .args(args)
            .output()
            .expect("binary runs")
    };
    let base = ["cluster", "--replicas", "2", "--tp", "8", "--requests", "2", "--tokens", "4"];

    let mut args = base.to_vec();
    args.extend(["--roles", "sideways"]);
    let out = run(&args);
    assert_eq!(out.status.code(), Some(2), "unknown --roles must exit 2");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("colocated") && stderr.contains("split"), "{stderr}");

    let mut args = base.to_vec();
    args.extend(["--xfer", "carrier-pigeon"]);
    let out = run(&args);
    assert_eq!(out.status.code(), Some(2), "unknown --xfer must exit 2");
    let stderr = String::from_utf8_lossy(&out.stderr);
    for name in fa3_split::cluster::INTERCONNECT_NAMES {
        assert!(stderr.contains(name), "help should list {name}: {stderr}");
    }

    // Split pools without the two-stage router is a topology/router
    // mismatch, reported as an error (nonzero), not a hang or a panic.
    let mut args = base.to_vec();
    args.extend(["--roles", "split", "--router", "round-robin"]);
    let out = run(&args);
    assert!(!out.status.success(), "split + single-stage router must fail");
}
