//! The zero-allocation decode guarantee **with preemption enabled**
//! (DESIGN.md §Overload survival).
//!
//! PR 9 added a preemption check to the front of every engine step.
//! This guard pins down its steady-state cost: with `preemption.enabled
//! = true` but no blocked higher-class head (the common case — overload
//! is the exception, not the rule), the check must decide "nothing to
//! do" without touching the heap. Victim selection, KV release, swap
//! ledger writes, and re-admission are all cold-path work that only
//! runs when a preemption actually fires.
//!
//! Same single-`#[test]` discipline as `alloc_guard.rs`: the counting
//! allocator is process-global, so the measured window gets the binary
//! to itself.

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{Engine, EngineConfig, PreemptionConfig, Request};
use fa3_split::planner::Planner;
use fa3_split::util::alloc_counter::{self, CountingAllocator};

#[global_allocator]
static COUNTER: CountingAllocator = CountingAllocator;

#[test]
fn steady_state_decode_allocates_nothing_with_preemption_enabled() {
    let cfg = EngineConfig {
        preemption: PreemptionConfig { enabled: true, ..Default::default() },
        ..Default::default()
    };
    let mut engine = Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 2048 })
        .config(cfg)
        .build()
        .unwrap();
    // Same-priority fire-and-forget submissions: nothing ever blocks a
    // higher class, so the per-step preemption probe runs and declines
    // on every one of the measured steps.
    drop(engine.submit(Request::new(1, vec![1; 350], 400)).unwrap());
    drop(engine.submit(Request::new(2, vec![1; 350], 400)).unwrap());

    for _ in 0..24 {
        engine.step().unwrap();
    }
    assert!(engine.waiting_len() == 0 && engine.running_len() == 2, "warmup should settle");
    engine.metrics.reserve_capacity(256, 16);

    let before = alloc_counter::total_allocations();
    for _ in 0..100 {
        engine.step().unwrap();
    }
    let allocated = alloc_counter::total_allocations() - before;

    assert_eq!(
        allocated, 0,
        "the enabled-but-idle preemption probe must not allocate \
         (got {allocated} over 100 steps)"
    );
    // The probe never found a blocked head, so nothing was preempted.
    assert_eq!(engine.metrics.preemptions, 0);
    assert_eq!(engine.running_len(), 2);

    let done = engine.run_until_idle().unwrap();
    assert_eq!(done.len(), 2);
    assert!(done.iter().all(|f| f.tokens.len() == 400));
}
