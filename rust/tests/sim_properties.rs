//! Property tests over the H100 latency simulator.
//!
//! The regression claim (§5.3) generalized: across randomized shape space
//! the sequence-aware policy never loses to the standard one on the
//! simulator, latencies decompose consistently, and the model behaves
//! monotonically where physics says it must. All launch schedules come
//! from the planner façade (plan / plan_forced), never hand-built.

use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::heuristics::DispatchPath;
use fa3_split::planner::Planner;
use fa3_split::sim::Simulator;
use fa3_split::util::proptest_lite::{check, check_with, Config, Domain};

fn shape_from(case: &[u64]) -> DecodeShape {
    DecodeShape::decode(
        case[0] as usize,
        case[1] as usize,
        8 * case[2] as usize,
        case[2] as usize,
        128,
    )
}

const SHAPE_DOMAINS: [Domain; 3] = [
    Domain { lo: 1, hi: 16 },
    Domain { lo: 1, hi: 9000 },
    Domain { lo: 1, hi: 32 },
];

#[test]
fn patched_policy_never_regresses_anywhere() {
    // The paper's ">= 0.99x across all configurations", property-tested
    // over the whole randomized shape space (noise-free model, so the
    // bound is exact: patched <= standard).
    let cfg = Config { cases: 2000, ..Default::default() };
    check_with(cfg, "no-regression-anywhere", &SHAPE_DOMAINS, |case| {
        let sim = Simulator::h100();
        let shape = shape_from(case);
        let t_std = sim.kernel_us(&Planner::standard().plan(&shape).metadata);
        let t_pat = sim.kernel_us(&Planner::sequence_aware().plan(&shape).metadata);
        if t_pat > t_std * 1.0000001 {
            return Err(format!(
                "regression at B={} L_K={} H_KV={}: {t_pat:.3} > {t_std:.3}",
                shape.batch, shape.l_k, shape.h_kv
            ));
        }
        Ok(())
    });
}

#[test]
fn latency_decomposition_adds_up() {
    check("timing-decomposition", &SHAPE_DOMAINS, |case| {
        let sim = Simulator::h100();
        let shape = shape_from(case);
        let md = Planner::sequence_aware().plan(&shape).metadata;
        let t = sim.kernel(&md);
        let sum = t.launch_us + t.body_us + t.combine_us;
        if (t.total_us - sum).abs() > 1e-9 {
            return Err(format!("total {:.4} != parts {:.4}", t.total_us, sum));
        }
        if t.total_us < sim.cal.overhead_us() {
            return Err("latency below fixed overhead".into());
        }
        if t.waves == 0 || t.active_ctas == 0 {
            return Err("degenerate wave/cta count".into());
        }
        Ok(())
    });
}

#[test]
fn longer_context_never_faster_unsplit() {
    // At s = 1 (pure serial streaming) more KV blocks strictly add body
    // time. (For forced s > 1 this is NOT a theorem: a longer context can
    // rebalance onto fewer non-empty splits and a cheaper combine —
    // observed at e.g. B=2, L_K=1409→1921, s=12 — so the property is
    // stated only where physics demands it.)
    check(
        "monotone-in-lk",
        &[Domain::new(1, 4), Domain::new(1, 4000), Domain::new(1, 8)],
        |case| {
            let sim = Simulator::h100();
            let planner = Planner::standard();
            let (b, l_k, h_kv) = (case[0] as usize, case[1] as usize, case[2] as usize);
            let t1 = sim.kernel_us(
                &planner.plan_forced(&DecodeShape::decode(b, l_k, 8 * h_kv, h_kv, 128), 1).metadata,
            );
            let t2 = sim.kernel_us(
                &planner
                    .plan_forced(&DecodeShape::decode(b, l_k + 512, 8 * h_kv, h_kv, 128), 1)
                    .metadata,
            );
            if t2 + 1e-9 < t1 {
                return Err(format!("longer context faster: {t2:.3} < {t1:.3}"));
            }
            Ok(())
        },
    );
}

#[test]
fn wave_quantization_monotone_in_batch() {
    // More batch rows (tiles) never reduce latency at fixed s.
    check(
        "monotone-in-batch",
        &[Domain::new(1, 12), Domain::new(1, 4000), Domain::new(1, 32)],
        |case| {
            let sim = Simulator::h100();
            let planner = Planner::standard();
            let (b, l_k, h_kv) = (case[0] as usize, case[1] as usize, case[2] as usize);
            let t1 = sim.kernel_us(
                &planner.plan_forced(&DecodeShape::decode(b, l_k, 8 * h_kv, h_kv, 128), 1).metadata,
            );
            let t2 = sim.kernel_us(
                &planner
                    .plan_forced(&DecodeShape::decode(b * 2, l_k, 8 * h_kv, h_kv, 128), 1)
                    .metadata,
            );
            if t2 + 1e-9 < t1 {
                return Err(format!("doubling batch got faster: {t2:.3} < {t1:.3}"));
            }
            Ok(())
        },
    );
}

#[test]
fn internal_path_never_beats_metadata_path() {
    check("internal-path-penalty", &SHAPE_DOMAINS, |case| {
        let sim = Simulator::h100();
        let shape = shape_from(case);
        let md = Planner::sequence_aware().plan(&shape).metadata;
        let t_meta = sim.kernel_us(&md);
        let t_int = sim.kernel_us(&md.with_path(DispatchPath::InternalHeuristic));
        if t_int + 1e-9 < t_meta {
            return Err(format!("internal path faster: {t_int:.3} < {t_meta:.3}"));
        }
        Ok(())
    });
}

#[test]
fn oversplit_never_starves_work() {
    // Any forced s >= 1 must produce finite positive latency.
    check(
        "oversplit-safety",
        &[Domain::new(1, 4), Domain::new(1, 2000), Domain::new(1, 8), Domain::new(1, 128)],
        |case| {
            let sim = Simulator::h100();
            let shape = DecodeShape::decode(
                case[0] as usize,
                case[1] as usize,
                8 * case[2] as usize,
                case[2] as usize,
                128,
            );
            let md = Planner::standard().plan_forced(&shape, case[3] as usize).metadata;
            let t = sim.kernel(&md);
            if !t.total_us.is_finite() || t.total_us <= 0.0 {
                return Err(format!("bad latency {:?}", t.total_us));
            }
            Ok(())
        },
    );
}
