//! Preemption / swap / resume invariants (DESIGN.md §Overload survival),
//! property-style:
//!
//! 1. **KV blocks never leak or double-free** — under random
//!    interleavings of submissions, preemptions, swap/recompute resumes,
//!    cancellations, and steps, block accounting balances at every step
//!    boundary and a full drain returns every block.
//! 2. **Resumed streams are byte-identical** — a preempted-then-resumed
//!    request finishes with exactly the token stream of a never-preempted
//!    run (position-pure regeneration on the recompute path, parked KV on
//!    the swap path), and its streaming handle never re-sends or skips an
//!    index.
//! 3. **Preemption actually pays** — the deterministic two-request
//!    scenario's interactive TTFT beats the same scenario with
//!    preemption off.

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{
    BatcherConfig, BlockManagerConfig, Engine, EngineConfig, FinishReason, PreemptionConfig,
    Priority, Request, ResumePolicy, SloConfig, StreamEvent, SubmitOptions,
};
use fa3_split::planner::Planner;
use fa3_split::util::prng::Rng;
use fa3_split::util::proptest_lite::{check, Domain};
use fa3_split::workload::ChatWorkload;

fn engine(max_batch: usize, num_blocks: usize, preemption: PreemptionConfig) -> Engine {
    let buckets: Vec<usize> = [1, 2, 4, 8].into_iter().filter(|&b| b <= max_batch).collect();
    let cfg = EngineConfig {
        batcher: BatcherConfig { max_batch: *buckets.last().unwrap(), batch_buckets: buckets },
        blocks: BlockManagerConfig {
            block_size: 16,
            num_blocks,
            max_seq: 1024,
            ..Default::default()
        },
        preemption,
        ..Default::default()
    };
    Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(cfg)
        .build()
        .unwrap()
}

fn preempt_on(resume: ResumePolicy) -> PreemptionConfig {
    PreemptionConfig { enabled: true, resume, ..Default::default() }
}

/// The expected position-pure stream for a prompt of `prompt_len`:
/// generated token `i` sits at cache position `prompt_len + i`.
fn expected_tokens(prompt_len: usize, n: usize) -> Vec<i32> {
    (0..n).map(|i| SimBackend::synthetic_token(prompt_len + i)).collect()
}

// ----------------------------------------------------------------------
// 1. Block accounting under random preempt/resume/cancel interleavings.
// ----------------------------------------------------------------------

#[test]
fn preemption_interleavings_never_leak_kv_blocks() {
    check(
        "preempt-kv-accounting",
        &[Domain::new(0, 2), Domain::new(8, 48), Domain::new(0, u64::MAX)],
        |case| {
            let resume = match case[0] {
                0 => ResumePolicy::Auto,
                1 => ResumePolicy::Swap,
                _ => ResumePolicy::Recompute,
            };
            let num_blocks = case[1] as usize * 4;
            let mut rng = Rng::new(case[2]);
            let mut e = engine(2, num_blocks, preempt_on(resume));
            // Mixed-class open-loop overload: interactive arrivals keep
            // hitting slots held by standard/batch victims, so preempt,
            // park, resume, and shed all actually engage.
            let trace = ChatWorkload::mixed_open_loop(rng.next_u64(), 24, 40);
            let mut handles = Vec::new();
            for g in trace {
                let h = e
                    .submit_at_with(
                        g.request,
                        g.arrival_offset_us,
                        SubmitOptions::default().priority(g.priority),
                    )
                    .map_err(|err| format!("submit: {err}"))?;
                handles.push(h);
            }
            let mut steps = 0usize;
            while !e.is_idle() {
                e.step().map_err(|err| format!("step: {err:#}"))?;
                // Random mid-flight cancels race the preemption machinery:
                // a victim can be cancelled while parked or while running.
                if rng.range(0, 9) == 0 && !handles.is_empty() {
                    handles[rng.range(0, handles.len() - 1)].cancel();
                }
                let blocks = e.block_manager();
                blocks.check_invariants().map_err(|err| format!("{err:#}"))?;
                if blocks.used_blocks() > num_blocks {
                    return Err(format!(
                        "{} blocks in use, budget {num_blocks}",
                        blocks.used_blocks()
                    ));
                }
                steps += 1;
                if steps > 20_000 {
                    return Err("engine failed to drain".into());
                }
            }
            let blocks = e.block_manager();
            blocks.check_invariants().map_err(|err| format!("{err:#}"))?;
            if blocks.num_seqs() != 0 {
                return Err(format!("{} sequences leaked after drain", blocks.num_seqs()));
            }
            if blocks.free_blocks() != num_blocks {
                return Err(format!(
                    "blocks leaked: {} of {num_blocks} free after drain",
                    blocks.free_blocks()
                ));
            }
            Ok(())
        },
    );
}

// ----------------------------------------------------------------------
// 2. Resumed streams byte-identical to never-preempted runs.
// ----------------------------------------------------------------------

/// Two requests, one slot: a Batch victim decodes until an Interactive
/// arrival preempts it mid-stream. Returns the engine plus the finished
/// requests sorted by id (victim first).
fn preempt_scenario(resume: ResumePolicy) -> (Engine, Vec<fa3_split::coordinator::FinishedRequest>) {
    let mut e = engine(1, 128, preempt_on(resume));
    e.submit_at_with(
        Request::new(0, vec![7; 64], 32),
        0,
        SubmitOptions::default().priority(Priority::Batch),
    )
    .unwrap();
    e.submit_at_with(
        Request::new(1, vec![9; 32], 4),
        150,
        SubmitOptions::default().priority(Priority::Interactive),
    )
    .unwrap();
    let mut done = e.run_until_idle().unwrap();
    done.sort_by_key(|f| f.id);
    (e, done)
}

#[test]
fn resumed_stream_is_byte_identical_per_resume_policy() {
    // The never-preempted reference: the victim alone.
    let mut solo = engine(1, 128, PreemptionConfig::default());
    solo.submit(Request::new(0, vec![7; 64], 32)).unwrap();
    let reference = solo.run_until_idle().unwrap();
    assert_eq!(reference.len(), 1);
    assert_eq!(reference[0].tokens, expected_tokens(64, 32));

    for resume in [ResumePolicy::Swap, ResumePolicy::Recompute, ResumePolicy::Auto] {
        let (e, done) = preempt_scenario(resume);
        assert_eq!(e.metrics.preemptions, 1, "{resume:?}: the victim must be preempted");
        assert_eq!(
            e.metrics.resumes_swap + e.metrics.resumes_recompute,
            1,
            "{resume:?}: the victim must resume"
        );
        match resume {
            ResumePolicy::Swap => assert_eq!(e.metrics.resumes_swap, 1),
            ResumePolicy::Recompute => assert_eq!(e.metrics.resumes_recompute, 1),
            ResumePolicy::Auto => {}
        }
        assert_eq!(done.len(), 2);
        let victim = &done[0];
        assert_eq!(victim.reason, FinishReason::Length, "{resume:?}");
        assert_eq!(
            victim.tokens, reference[0].tokens,
            "{resume:?}: resumed stream diverged from the uncontended run"
        );
        // The interloper is untouched by the machinery.
        assert_eq!(done[1].tokens, expected_tokens(32, 4), "{resume:?}");
    }
}

#[test]
fn resumed_handle_never_resends_or_skips_an_index() {
    for resume in [ResumePolicy::Swap, ResumePolicy::Recompute] {
        let mut e = engine(1, 128, preempt_on(resume));
        let victim = e
            .submit_at_with(
                Request::new(0, vec![7; 64], 32),
                0,
                SubmitOptions::default().priority(Priority::Batch),
            )
            .unwrap();
        e.submit_at_with(
            Request::new(1, vec![9; 32], 4),
            150,
            SubmitOptions::default().priority(Priority::Interactive),
        )
        .unwrap();
        e.run_until_idle().unwrap();
        assert_eq!(e.metrics.preemptions, 1, "{resume:?}");
        let mut indices = Vec::new();
        while let Some(ev) = victim.try_event() {
            if let StreamEvent::Token { index, .. } = ev {
                indices.push(index);
            }
        }
        let want: Vec<usize> = (0..32).collect();
        assert_eq!(indices, want, "{resume:?}: stream indices must be 0..32 exactly once");
    }
}

// ----------------------------------------------------------------------
// 3. The payoff, and goodput accounting.
// ----------------------------------------------------------------------

#[test]
fn preemption_cuts_interactive_ttft_in_the_blocked_head_scenario() {
    let (_, with) = preempt_scenario(ResumePolicy::Auto);
    // Same two requests, preemption off: the interactive arrival waits
    // for the victim's full 32-token decode.
    let mut off = engine(1, 128, PreemptionConfig::default());
    off.submit_at_with(
        Request::new(0, vec![7; 64], 32),
        0,
        SubmitOptions::default().priority(Priority::Batch),
    )
    .unwrap();
    off.submit_at_with(
        Request::new(1, vec![9; 32], 4),
        150,
        SubmitOptions::default().priority(Priority::Interactive),
    )
    .unwrap();
    let mut without = off.run_until_idle().unwrap();
    without.sort_by_key(|f| f.id);
    assert_eq!(off.metrics.preemptions, 0);
    let ttft_with = with[1].timing.ttft_us();
    let ttft_without = without[1].timing.ttft_us();
    assert!(
        ttft_with < ttft_without,
        "interactive TTFT {ttft_with}µs !< refusal-only {ttft_without}µs"
    );
}

#[test]
fn goodput_counts_slo_met_streams_and_misses_the_rest() {
    // One uncontended request trivially meets the default targets.
    let mut cfg = EngineConfig {
        batcher: BatcherConfig::for_max_batch(1),
        slo: Some(SloConfig::default()),
        ..Default::default()
    };
    cfg.blocks.max_seq = 1024;
    let mut e = Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(cfg)
        .build()
        .unwrap();
    e.submit(Request::new(0, vec![7; 64], 16)).unwrap();
    let done = e.run_until_idle().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(e.metrics.goodput_tokens, 16);
    assert_eq!(e.metrics.slo_misses, 0);
    assert!(e.metrics.goodput_tok_s() > 0.0);
}
