//! Property tests over the planner façade (proptest_lite).
//!
//! The planner's core guarantees, checked across randomized shape space:
//!
//! * **Cache transparency** — a cached planner returns plans identical to
//!   an uncached one, for every registered policy and for genome sources
//!   (the shape-bucket key is only sound because policies are
//!   bucket-pure; this test is what keeps that contract honest).
//! * **Batch equivalence** — `plan_batch` equals element-wise per-shape
//!   `plan`.
//! * **Eviction safety** — a capacity-starved LRU still returns correct
//!   plans (eviction can only cost speed, never correctness).
//! * **Knob safety** — oversized `sm_margin` saturates instead of
//!   panicking, and every derived quantity stays in range.
//! * **Cursor equivalence** — a `PlanCursor` is element-wise identical to
//!   `Planner::plan` over an exhaustive `L_K` 1..=4096 sweep for every
//!   registered policy and the figure-1 genome, and over randomized
//!   non-monotone (batch, L_K) trajectories (horizon crossings at exact
//!   nblk bucket edges and genome rule boundaries included).

use std::cell::RefCell;

use fa3_split::evolve::Genome;
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::planner::{DeviceProfile, PlanCursor, Planner, PlannerBuilder, PolicyRegistry};
use fa3_split::util::proptest_lite::{check, check_with, Config, Domain};

fn shape_from(case: &[u64]) -> DecodeShape {
    DecodeShape::decode(
        case[0] as usize,
        case[1] as usize,
        8 * case[2] as usize,
        case[2] as usize,
        128,
    )
}

const SHAPE_DOMAINS: [Domain; 3] = [
    Domain { lo: 1, hi: 16 },   // batch
    Domain { lo: 1, hi: 9000 }, // l_k
    Domain { lo: 1, hi: 32 },   // h_kv
];

#[test]
fn cached_plans_equal_uncached_for_every_registered_policy() {
    let registry = PolicyRegistry::builtin();
    for name in ["standard", "sequence-aware", "extended", "evolved-genome"] {
        // Tune/construct once per policy (the extended table is expensive);
        // RefCell because proptest_lite closures are `Fn`.
        let cached = RefCell::new(registry.planner(name).unwrap());
        let uncached = RefCell::new(registry.builder(name).unwrap().cache_capacity(0).build());
        check_with(
            Config { cases: 600, ..Default::default() },
            &format!("cache-transparent-{name}"),
            &SHAPE_DOMAINS,
            |case| {
                let shape = shape_from(case);
                let a = cached.borrow_mut().plan(&shape);
                let b = uncached.borrow_mut().plan(&shape);
                if a != b {
                    return Err(format!("cached {a:?} != uncached {b:?}"));
                }
                Ok(())
            },
        );
        let stats = cached.borrow().cache_stats();
        assert!(stats.hits + stats.misses >= 600, "{name}: cache untouched? {stats:?}");
    }
}

#[test]
fn genome_planner_cache_is_transparent_for_figure1() {
    // Genome sources key by exact L_K (rules carry arbitrary ranges);
    // transparency must hold there too.
    let cached = RefCell::new(PlannerBuilder::genome(Genome::figure1()).build());
    let uncached =
        RefCell::new(PlannerBuilder::genome(Genome::figure1()).cache_capacity(0).build());
    check("cache-transparent-genome", &SHAPE_DOMAINS, |case| {
        let shape = shape_from(case);
        let a = cached.borrow_mut().plan(&shape);
        let b = uncached.borrow_mut().plan(&shape);
        if a != b {
            return Err(format!("cached {a:?} != uncached {b:?}"));
        }
        Ok(())
    });
}

#[test]
fn plan_batch_equals_per_shape_plan() {
    // Random batches of 1..=8 shapes, derived deterministically from the
    // sampled case: plan_batch must agree element-wise with plan() on a
    // fresh planner.
    let domains = [
        Domain { lo: 1, hi: 8 },    // batch-of-shapes size
        Domain { lo: 1, hi: 9000 }, // base l_k
        Domain { lo: 1, hi: 8 },    // h_kv
        Domain { lo: 1, hi: 16 },   // batch dim
    ];
    check("plan-batch-equivalence", &domains, |case| {
        let n = case[0] as usize;
        let shapes: Vec<DecodeShape> = (0..n)
            .map(|i| {
                // Spread the l_k values so batches cross bucket boundaries.
                let l_k = ((case[1] as usize + i * 97 - 1) % 9000) + 1;
                DecodeShape::decode(
                    case[3] as usize,
                    l_k,
                    8 * case[2] as usize,
                    case[2] as usize,
                    128,
                )
            })
            .collect();
        let batch = PlannerBuilder::policy(fa3_split::heuristics::SequenceAwarePolicy)
            .build()
            .plan_batch(&shapes);
        let mut single = Planner::sequence_aware();
        for (i, shape) in shapes.iter().enumerate() {
            let expect = single.plan(shape);
            if batch[i] != expect {
                return Err(format!("index {i}: batch {:?} != single {expect:?}", batch[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn cursor_is_byte_identical_over_exhaustive_lk_sweeps() {
    // The acceptance sweep: every registered policy plus the figure-1
    // genome, every L_K in 1..=4096 (decode monotonicity — exactly the
    // trajectory a serving request walks), for the paper's B=1 shape and
    // a batched one. The cursor must agree with a per-step plan() on a
    // separate planner to the bit (LaunchPlan derives PartialEq over its
    // f64 fields; both sides run the identical derivation, so exact
    // equality is the contract, not an approximation).
    let registry = PolicyRegistry::builtin();
    let sources: Vec<(&str, Box<dyn Fn() -> Planner>)> = vec![
        ("standard", Box::new(|| PolicyRegistry::builtin().planner("standard").unwrap())),
        ("sequence-aware", Box::new(|| {
            PolicyRegistry::builtin().planner("sequence-aware").unwrap()
        })),
        ("extended", Box::new(|| PolicyRegistry::builtin().planner("extended").unwrap())),
        ("evolved-genome", Box::new(|| PlannerBuilder::genome(Genome::figure1()).build())),
    ];
    assert_eq!(registry.names().len(), 4, "new policies must join this sweep");
    for (name, make) in &sources {
        for batch in [1usize, 2] {
            let mut cursored = make();
            let mut oracle = make();
            let mut cursor = cursored.cursor();
            let mut refills = 0;
            for l_k in 1..=4096usize {
                let shape = DecodeShape::llama70b_tp8(batch, l_k);
                let before = cursor.stats().refills;
                let got = cursor.plan(&mut cursored, &shape);
                let want = oracle.plan(&shape);
                assert_eq!(got, want, "{name} b={batch} l_k={l_k}");
                refills += (cursor.stats().refills - before) as usize;
                // A refill may only happen where a window legitimately
                // ends: at a bucket entry (l_k ≡ 1 mod 128), a genome rule
                // edge, or the very first step.
                if cursor.stats().refills > before && *name != "evolved-genome" {
                    assert!(
                        l_k == 1 || (l_k - 1) % 128 == 0,
                        "{name} b={batch}: unexpected refill at l_k={l_k}"
                    );
                }
            }
            // 4096 tokens = 32 nblk buckets: bucket-pure policies refill
            // exactly once per bucket; the genome adds its rule edges
            // (255|256 and 512|513 for figure1) but stays O(buckets).
            assert!(
                (32..=40).contains(&refills),
                "{name} b={batch}: {refills} refills over 4096 steps"
            );
        }
    }
}

#[test]
fn cursor_matches_plan_on_random_trajectories() {
    // Non-monotone L_K jumps and batch flips on a single cursor: the
    // validity window's *lower* edge and the pinned-key check must hold,
    // not just the decode-forward horizon. One shared planner + cursor
    // accumulates state across cases (that persistence is the point).
    let cursored = RefCell::new((Planner::sequence_aware(), PlanCursor::new()));
    let oracle = RefCell::new(Planner::sequence_aware());
    check("cursor-random-trajectories", &SHAPE_DOMAINS, |case| {
        let shape = shape_from(case);
        let mut guard = cursored.borrow_mut();
        let (planner, cursor) = &mut *guard;
        let got = cursor.plan(planner, &shape);
        let want = oracle.borrow_mut().plan(&shape);
        if got != want {
            return Err(format!("cursor {got:?} != plan {want:?}"));
        }
        Ok(())
    });
}

#[test]
fn cursor_matches_genome_plan_on_random_trajectories() {
    let cursored = RefCell::new({
        let p = PlannerBuilder::genome(Genome::figure1()).build();
        let c = p.cursor();
        (p, c)
    });
    let oracle = RefCell::new(PlannerBuilder::genome(Genome::figure1()).build());
    check("cursor-random-genome", &SHAPE_DOMAINS, |case| {
        let shape = shape_from(case);
        let mut guard = cursored.borrow_mut();
        let (planner, cursor) = &mut *guard;
        let got = cursor.plan(planner, &shape);
        let want = oracle.borrow_mut().plan(&shape);
        if got != want {
            return Err(format!("genome cursor {got:?} != plan {want:?}"));
        }
        Ok(())
    });
}

#[test]
fn plan_batch_into_equals_plan_batch_and_reuses_capacity() {
    let shapes: Vec<DecodeShape> = (0..6)
        .map(|i| DecodeShape::llama70b_tp8(1 + i % 2, 300 + i * 97))
        .collect();
    let mut a = Planner::sequence_aware();
    let mut b = Planner::sequence_aware();
    let mut out = Vec::new();
    a.plan_batch_into(&mut out, &shapes);
    assert_eq!(out, b.plan_batch(&shapes));
    let cap = out.capacity();
    a.plan_batch_into(&mut out, &shapes);
    assert_eq!(out.capacity(), cap, "output buffer must be reused across steps");
}

#[test]
fn tiny_cache_capacity_only_costs_speed_never_correctness() {
    // Capacity 2 with shapes cycling through 4+ buckets: constant
    // eviction, same answers.
    let tiny = RefCell::new(
        PlannerBuilder::policy(fa3_split::heuristics::SequenceAwarePolicy)
            .cache_capacity(2)
            .build(),
    );
    check("lru-eviction-correct", &SHAPE_DOMAINS, |case| {
        let shape = shape_from(case);
        let a = tiny.borrow_mut().plan(&shape);
        let b = Planner::sequence_aware().plan(&shape);
        if a != b {
            return Err(format!("evicting cache diverged: {a:?} != {b:?}"));
        }
        Ok(())
    });
    let stats = tiny.borrow().cache_stats();
    assert!(stats.entries <= 2, "{stats:?}");
}

#[test]
fn derived_plan_quantities_stay_in_range() {
    let domains = [
        Domain { lo: 1, hi: 16 },
        Domain { lo: 1, hi: 9000 },
        Domain { lo: 1, hi: 32 },
        Domain { lo: 0, hi: 300 }, // sm_margin, intentionally > 132 sometimes
    ];
    check("plan-ranges", &domains, |case| {
        let shape = shape_from(case);
        let mut planner = PlannerBuilder::policy(fa3_split::heuristics::SequenceAwarePolicy)
            .sm_margin(case[3] as usize)
            .build();
        let plan = planner.plan(&shape);
        if !(0.0..=1.0).contains(&plan.occupancy) {
            return Err(format!("occupancy {} out of range", plan.occupancy));
        }
        if plan.num_splits() < 1 || plan.num_splits() > DeviceProfile::H100_SXM.max_splits {
            return Err(format!("num_splits {} out of range", plan.num_splits()));
        }
        if plan.effective_splits > plan.num_splits() || plan.effective_splits == 0 {
            return Err(format!("effective splits {} out of range", plan.effective_splits));
        }
        if plan.grid_ctas == 0 || plan.waves == 0 {
            return Err("degenerate grid".into());
        }
        if plan.combine_estimate_us < 0.0 {
            return Err("negative combine estimate".into());
        }
        // The metadata-side occupancy helper must agree and must not
        // panic for oversized margins (the seed's underflow bug).
        let occ = plan.metadata.occupancy();
        if (occ - plan.occupancy).abs() > 1e-12 {
            return Err(format!("metadata occupancy {occ} != plan {}", plan.occupancy));
        }
        Ok(())
    });
}

#[test]
fn device_profiles_share_the_decision_structure() {
    // On any preset, a saturated grid stays unsplit and the boundary
    // override stays within the device's split cap.
    for device in DeviceProfile::presets() {
        let sat = RefCell::new(
            PlannerBuilder::policy(fa3_split::heuristics::SequenceAwarePolicy)
                .device(device)
                .build(),
        );
        check_with(
            Config { cases: 300, ..Default::default() },
            &format!("profile-sanity-{}", device.name),
            &SHAPE_DOMAINS,
            |case| {
                let shape = shape_from(case);
                let plan = sat.borrow_mut().plan(&shape);
                let tiles = shape.total_mblocks(true);
                if tiles as f32 >= 0.8 * device.num_sms as f32 && plan.num_splits() != 1 {
                    return Err(format!(
                        "saturated grid split on {}: tiles={tiles} s={}",
                        device.name,
                        plan.num_splits()
                    ));
                }
                if plan.num_splits() > device.max_splits {
                    return Err(format!("split cap violated on {}", device.name));
                }
                Ok(())
            },
        );
    }
}
