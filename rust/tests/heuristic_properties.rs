//! Property tests over the split heuristics (proptest_lite).
//!
//! The paper's safety story rests on structural properties of the policy
//! pair, not on the 160 sampled configs alone — these check them across
//! randomized shape space.

use fa3_split::heuristics::sequence_aware::{BOUNDARY_SPLIT, LOW_TILE_THRESHOLD};
use fa3_split::heuristics::tiles::{DecodeShape, SplitGeometry, KV_BLOCK};
use fa3_split::heuristics::{SequenceAwarePolicy, SplitPolicy, StandardPolicy};
use fa3_split::planner::{DeviceProfile, Planner};
use fa3_split::util::proptest_lite::{check, Domain};

const H100_SMS: usize = DeviceProfile::H100_SXM.num_sms;

fn shape_from(case: &[u64]) -> DecodeShape {
    let batch = case[0] as usize;
    let l_k = case[1] as usize;
    let h_kv = case[2] as usize;
    DecodeShape::decode(batch, l_k, 8 * h_kv, h_kv, 128)
}

const SHAPE_DOMAINS: [Domain; 3] = [
    Domain { lo: 1, hi: 16 },    // batch
    Domain { lo: 1, hi: 9000 },  // l_k
    Domain { lo: 1, hi: 32 },    // h_kv
];

#[test]
fn policies_differ_only_in_the_boundary_bucket() {
    check("policy-delta-surface", &SHAPE_DOMAINS, |case| {
        let shape = shape_from(case);
        let s_std = StandardPolicy.num_splits(&shape, H100_SMS, true);
        let s_pat = SequenceAwarePolicy.num_splits(&shape, H100_SMS, true);
        if s_std == s_pat {
            return Ok(());
        }
        // Any difference must be exactly the paper's override.
        if shape.nblk() != 4 {
            return Err(format!("diff outside nblk=4: nblk={}", shape.nblk()));
        }
        if shape.total_mblocks(true) >= LOW_TILE_THRESHOLD {
            return Err(format!("diff with tiles={}", shape.total_mblocks(true)));
        }
        if s_std != 1 || s_pat != BOUNDARY_SPLIT {
            return Err(format!("unexpected values {s_std} -> {s_pat}"));
        }
        Ok(())
    });
}

#[test]
fn patched_never_splits_saturated_grids() {
    check("saturated-stays-unsplit", &SHAPE_DOMAINS, |case| {
        let shape = shape_from(case);
        let tiles = shape.total_mblocks(true);
        let s = SequenceAwarePolicy.num_splits(&shape, H100_SMS, true);
        if tiles as f32 >= 0.8 * H100_SMS as f32 && s != 1 {
            return Err(format!("saturated grid split: tiles={tiles} s={s}"));
        }
        Ok(())
    });
}

#[test]
fn split_counts_bounded_by_caps() {
    check("split-caps", &SHAPE_DOMAINS, |case| {
        let shape = shape_from(case);
        for (name, s) in [
            ("std", StandardPolicy.num_splits(&shape, H100_SMS, true)),
            ("pat", SequenceAwarePolicy.num_splits(&shape, H100_SMS, true)),
        ] {
            if s < 1 || s > 128 || s > H100_SMS.max(shape.nblk()).max(3) {
                return Err(format!("{name}: s={s} out of bounds (nblk={})", shape.nblk()));
            }
        }
        Ok(())
    });
}

#[test]
fn geometry_invariants() {
    check(
        "split-geometry",
        &[Domain::new(1, 20_000), Domain::new(1, 128)],
        |case| {
            let (l_k, s) = (case[0] as usize, case[1] as usize);
            let g = SplitGeometry::of(l_k, s);
            if g.padded_len < l_k {
                return Err("padding lost tokens".into());
            }
            if g.split_len != g.blocks_per_split * KV_BLOCK {
                return Err("split_len not block aligned".into());
            }
            let eff = SplitGeometry::effective_splits(l_k, s);
            if eff > s || eff > g.nblk || eff == 0 {
                return Err(format!("effective splits {eff} out of range"));
            }
            // Work conservation: the effective splits cover all blocks.
            if eff * g.blocks_per_split < g.nblk {
                return Err("blocks dropped".into());
            }
            Ok(())
        },
    );
}

#[test]
fn metadata_occupancy_and_ctas_consistent() {
    check("metadata-consistency", &SHAPE_DOMAINS, |case| {
        let shape = shape_from(case);
        let plan = Planner::sequence_aware().plan(&shape);
        let md = plan.metadata;
        let occ = md.occupancy();
        if !(0.0..=1.0).contains(&occ) {
            return Err(format!("occupancy {occ}"));
        }
        if (occ - plan.occupancy).abs() > 1e-12 {
            return Err(format!("plan occupancy {} != metadata {occ}", plan.occupancy));
        }
        if md.grid_ctas() == 0 {
            return Err("zero CTAs".into());
        }
        if plan.grid_ctas != md.grid_ctas() {
            return Err("plan CTA count disagrees with metadata".into());
        }
        let forced = Planner::standard().plan_forced(&shape, md.num_splits).metadata;
        if forced.grid_ctas() != md.grid_ctas() {
            return Err("forced metadata disagrees with policy metadata".into());
        }
        Ok(())
    });
}

#[test]
fn guard_region_is_sm_budget_independent() {
    // Across SM budgets (sm_margin sweep): decisions stay bounded and the
    // short-context guard holds regardless of the SM count.
    check(
        "sm-budget",
        &[Domain::new(1, 8), Domain::new(1, 4096), Domain::new(1, 8), Domain::new(0, 100)],
        |case| {
            let shape = DecodeShape::decode(
                case[0] as usize,
                case[1] as usize,
                8 * case[2] as usize,
                case[2] as usize,
                128,
            );
            let sms = H100_SMS - case[3] as usize;
            let s = SequenceAwarePolicy.num_splits(&shape, sms, true);
            if shape.nblk() <= 3 && s != 1 {
                return Err(format!("guard 1 violated at sms={sms}: s={s}"));
            }
            if s > sms.max(BOUNDARY_SPLIT) {
                return Err(format!("s={s} exceeds SM budget {sms}"));
            }
            Ok(())
        },
    );
}
