//! Prefix-sharing invariants (DESIGN.md §Prefix sharing), property-style.
//!
//! Three contracts the ref-counted, content-hashed block manager must
//! hold however submissions, cancellations, forks, and completions
//! interleave:
//!
//! 1. **Refcounts never leak or double-free** — after any interleaving,
//!    block accounting balances (free + evictable + active == total,
//!    Σ refcounts == Σ attachments) and a full drain returns every block.
//! 2. **Copy-on-write never mutates a shared block** — a donor's prefix
//!    chain matches bit-identically after any number of tail forks
//!    against it.
//! 3. **Disjoint workloads are byte-identical to the pre-sharing
//!    allocator** — with nothing sharable, `enable_prefix_sharing` on
//!    vs off produces the same tokens, reasons, and virtual-clock
//!    timings for every request.
//!
//! Plus the serving-level payoff the tentpole exists for: a shared
//! system-prompt fan-out admits more concurrently and reaches first
//! tokens sooner than the matched disjoint control at an equal KV
//! budget.

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{
    BatcherConfig, BlockManager, BlockManagerConfig, Engine, EngineConfig, FinishedRequest,
};
use fa3_split::planner::Planner;
use fa3_split::util::prng::Rng;
use fa3_split::util::proptest_lite::{check, Domain};
use fa3_split::workload::ChatWorkload;

fn engine_with(blocks: BlockManagerConfig, max_batch: usize) -> Engine {
    let cfg = EngineConfig {
        batcher: BatcherConfig::for_max_batch(max_batch),
        blocks,
        ..Default::default()
    };
    Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(cfg)
        .build()
        .unwrap()
}

#[test]
fn refcounts_never_leak_or_double_free_under_random_interleavings() {
    // Random admit / cow_fork / release sequences over prompts drawn
    // from a few "system prompt" families (so sharing, revival, and
    // eviction all actually engage), invariants checked at every step.
    check(
        "prefix-refcounts",
        &[Domain::new(4, 48), Domain::new(0, u64::MAX)],
        |case| {
            let num_blocks = case[0] as usize * 2;
            let mut rng = Rng::new(case[1]);
            let mut mgr = BlockManager::new(BlockManagerConfig {
                block_size: 8,
                num_blocks,
                max_seq: 8 * num_blocks,
                ..Default::default()
            });
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..250 {
                match rng.range(0, 3) {
                    0 | 1 => {
                        // A shared family prefix plus a unique suffix:
                        // full-block matches, tail matches, and misses
                        // all occur across the run.
                        let family = rng.range(0, 2) as i32;
                        let prefix_len = rng.range(0, 40);
                        let suffix_len = rng.range(1, 24);
                        let mut prompt: Vec<i32> =
                            (0..prefix_len).map(|i| family * 1_000 + i as i32).collect();
                        prompt.extend(
                            (0..suffix_len).map(|_| 100_000 + rng.range(0, 1 << 30) as i32),
                        );
                        let max_new = rng.range(0, 16);
                        if mgr.can_admit_prompt(&prompt, max_new) {
                            mgr.admit(next_id, &prompt, max_new)
                                .map_err(|e| format!("admit after check: {e}"))?;
                            live.push(next_id);
                            next_id += 1;
                        }
                    }
                    2 => {
                        // First-write fork on a random live sequence
                        // (idempotent when nothing is armed).
                        if !live.is_empty() {
                            let id = live[rng.range(0, live.len() - 1)];
                            mgr.cow_fork(id).map_err(|e| format!("cow_fork: {e}"))?;
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let idx = rng.range(0, live.len() - 1);
                            let id = live.swap_remove(idx);
                            mgr.release(id).map_err(|e| format!("release: {e}"))?;
                        }
                    }
                }
                mgr.check_invariants().map_err(|e| format!("{e}"))?;
                if mgr.free_blocks() > num_blocks {
                    return Err("free blocks exceed the budget".into());
                }
            }
            // Full drain: every block must come back, nothing double-freed.
            for id in live {
                mgr.release(id).map_err(|e| format!("drain release: {e}"))?;
            }
            mgr.check_invariants().map_err(|e| format!("{e}"))?;
            if mgr.num_seqs() != 0 {
                return Err("sequences leaked".into());
            }
            if mgr.free_blocks() != num_blocks {
                return Err(format!(
                    "blocks leaked: {} of {num_blocks} free after drain",
                    mgr.free_blocks()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn cow_fork_never_mutates_the_shared_donor_block() {
    // A fan of tail-sharing requests forks against one donor; after all
    // of them fork and finish, the donor's full chain must still match
    // bit-identically — the shared block was copied from, never written.
    check(
        "cow-immutability",
        &[Domain::new(1, 6), Domain::new(1, 15), Domain::new(0, u64::MAX)],
        |case| {
            let forkers = case[0] as usize;
            let tail = case[1] as usize; // 1..block_size-1: forces a partial tail
            let seed = case[2];
            let mut rng = Rng::new(seed);
            let mut mgr = BlockManager::new(BlockManagerConfig {
                block_size: 16,
                num_blocks: 256,
                max_seq: 1024,
                ..Default::default()
            });
            let donor: Vec<i32> = (0..48).map(|_| rng.range(1, 4000) as i32).collect();
            mgr.admit(0, &donor, 4).map_err(|e| format!("{e}"))?;
            for f in 0..forkers as u64 {
                // Prompt = donor's first full block(s) + a tail into the
                // donor's next block: arms a COW share.
                let prompt = donor[..32 + tail].to_vec();
                let grant = mgr.admit(1 + f, &prompt, 4).map_err(|e| format!("{e}"))?;
                if !grant.cow_pending {
                    return Err(format!("tail share did not arm (grant {grant:?})"));
                }
                let forked = mgr.cow_fork(1 + f).map_err(|e| format!("{e}"))?;
                if !forked {
                    return Err("armed fork did not fire".into());
                }
                mgr.check_invariants().map_err(|e| format!("{e}"))?;
            }
            for f in 0..forkers as u64 {
                mgr.release(1 + f).map_err(|e| format!("{e}"))?;
            }
            mgr.release(0).map_err(|e| format!("{e}"))?;
            // The donor chain survives intact: a fresh identical prompt
            // must match ALL its full blocks (any mutation would break
            // the content check on the touched block).
            let probe = mgr.probe(&donor);
            if probe.matched_blocks != 3 {
                return Err(format!(
                    "donor chain corrupted: {} of 3 blocks match after forks",
                    probe.matched_blocks
                ));
            }
            mgr.check_invariants().map_err(|e| format!("{e}"))?;
            Ok(())
        },
    );
}

fn run_workload(workload: &ChatWorkload, sharing: bool) -> (Vec<FinishedRequest>, u64) {
    let mut e = engine_with(
        BlockManagerConfig { enable_prefix_sharing: sharing, ..Default::default() },
        4,
    );
    for g in workload.generate() {
        e.submit_at(g.request, g.arrival_offset_us).expect("schedulable workload");
    }
    let mut done = e.run_until_idle().unwrap();
    done.sort_by_key(|f| f.id);
    (done, e.metrics.wall_us)
}

#[test]
fn disjoint_workloads_are_byte_identical_to_the_presharing_allocator() {
    // Random chat traffic (random token draws: nothing sharable) must be
    // bit-for-bit indistinguishable between sharing on and off — same
    // tokens, same reasons, same virtual-clock timings, same wall.
    check(
        "disjoint-identity",
        &[Domain::new(1, 20), Domain::new(0, u64::MAX)],
        |case| {
            let workload = ChatWorkload {
                seed: case[1],
                n_requests: case[0] as usize,
                prompt_median: 80,
                output_mean: 12,
                output_cap: 24,
                mean_gap_us: 400,
                ..Default::default()
            };
            let (with, wall_with) = run_workload(&workload, true);
            let (without, wall_without) = run_workload(&workload, false);
            if with.len() != without.len() {
                return Err(format!("{} vs {} finished", with.len(), without.len()));
            }
            if wall_with != wall_without {
                return Err(format!("wall diverged: {wall_with} vs {wall_without}"));
            }
            for (a, b) in with.iter().zip(&without) {
                let same = a.id == b.id
                    && a.tokens == b.tokens
                    && a.reason == b.reason
                    && a.prompt_len == b.prompt_len
                    && a.timing.arrival_us == b.timing.arrival_us
                    && a.timing.scheduled_us == b.timing.scheduled_us
                    && a.timing.first_token_us == b.timing.first_token_us
                    && a.timing.finished_us == b.timing.finished_us;
                if !same {
                    return Err(format!("request {} diverged under sharing", a.id));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shared_fanout_beats_disjoint_on_ttft_and_admitted_concurrency() {
    // The tentpole's acceptance shape at test scale: same lengths, same
    // arrivals, same KV budget — only the prefix grouping differs.
    let workload = |fanout: usize| ChatWorkload {
        seed: 42,
        n_requests: 24,
        shared_prefix_len: 256, // 16 blocks, block-aligned
        prefix_fanout: fanout,
        prompt_median: 48,
        prompt_min: 32,
        prompt_cap: 64,
        output_mean: 16,
        output_cap: 16,
        ..Default::default()
    };
    let run = |fanout: usize| {
        // 64 blocks = 1024 tokens: tight enough that disjoint requests
        // (~21 blocks each) queue on the block budget, while sharing
        // fits many more (16 shared + ~5 private each).
        let mut e = engine_with(
            BlockManagerConfig { num_blocks: 64, max_seq: 1024, ..Default::default() },
            8,
        );
        for g in workload(fanout).generate() {
            e.submit_at(g.request, g.arrival_offset_us).unwrap();
        }
        let done = e.run_until_idle().unwrap();
        assert_eq!(done.len(), 24);
        let mean_ttft = done.iter().map(|f| f.timing.ttft_us() as f64).sum::<f64>()
            / done.len() as f64;
        (mean_ttft, e.metrics.wall_us, e.metrics.prefix)
    };
    let (ttft_shared, wall_shared, stats_shared) = run(8);
    let (ttft_disjoint, wall_disjoint, stats_disjoint) = run(1);
    assert!(stats_shared.hits > 0, "{stats_shared:?}");
    assert_eq!(stats_disjoint.hits, 0, "disjoint control must not share");
    assert!(
        ttft_shared < ttft_disjoint,
        "shared TTFT {ttft_shared:.0}µs !< disjoint {ttft_disjoint:.0}µs"
    );
    assert!(
        wall_shared < wall_disjoint,
        "shared wall {wall_shared}µs !< disjoint {wall_disjoint}µs (admitted concurrency)"
    );
}
