//! The zero-allocation guarantee extended to mixed steps (DESIGN.md
//! §Continuous batching): a warmed-up engine running a steady
//! decode + chunked-prefill window must not touch the heap per step.
//!
//! The composed plan lives in engine scratch ([`MixedStepPlan`] refills
//! existing capacity), batch rows are a persistent pool (chunk rows
//! refill their prompt buffers in place), the decode wave and the chunk
//! wave each ride their own plan cursor, and the occupancy metrics for
//! chunk waves are scalar sums. Every chunk boundary — the cursor
//! advancing `chunk` tokens per step, including the plan-cursor refills
//! the growing context forces — happens inside the measured window.
//!
//! Single `#[test]` file: the allocation counter is process-global (same
//! constraint as `tests/alloc_guard.rs`, which guards the decode-only
//! hot path).

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{Engine, EngineConfig, Request};
use fa3_split::planner::Planner;
use fa3_split::schedule::{ScheduleConfig, TokenBudget};
use fa3_split::util::alloc_counter::{self, CountingAllocator};

#[global_allocator]
static COUNTER: CountingAllocator = CountingAllocator;

#[test]
fn steady_mixed_step_allocates_nothing_after_warmup() {
    let mut engine = Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 2048 })
        .config(EngineConfig {
            // Chunk = 8 with a 1200-token prompt: 150 mixed steps of
            // identical shape (1 decode row + 1 full-size chunk row), so
            // the measured window crosses a chunk boundary every step
            // without ever changing the composed row count.
            schedule: ScheduleConfig::bounded(8, TokenBudget::unbounded()),
            ..Default::default()
        })
        .build()
        .unwrap();
    // Dropped handles: the stream sinks latch dead on first send, so
    // streaming costs nothing inside the window (same contract as the
    // decode-only guard).
    drop(engine.submit(Request::new(1, vec![1; 200], 300)).unwrap());
    drop(engine.submit(Request::new(2, vec![1; 1200], 4)).unwrap());

    // Warmup: request 1's prompt chunks through (25 steps), its first
    // decode creates the decode-wave cursor and pushes its TTFT sample,
    // and the first mixed steps size the composer scratch, the chunk
    // row's prompt buffer, and the chunk-wave (l_q = 8) plan cursor.
    for _ in 0..40 {
        engine.step().unwrap();
    }
    assert!(engine.waiting_len() == 0 && engine.running_len() == 2, "warmup should settle");
    assert!(engine.metrics.mixed_steps > 0, "window precondition: mixed steps are running");
    engine.metrics.reserve_capacity(512, 16);

    let mixed_before = engine.metrics.mixed_steps;
    let before = alloc_counter::total_allocations();
    // 100 steps: request 2 chunks 800 more prompt tokens (still 250+
    // remaining at the end) while request 1 decodes — every step is a
    // mixed step with the same two rows, and the chunk wave's growing
    // context forces plan-cursor refills inside the window.
    for _ in 0..100 {
        engine.step().unwrap();
    }
    let allocated = alloc_counter::total_allocations() - before;

    assert_eq!(
        allocated, 0,
        "steady mixed steps must not allocate (got {allocated} over 100 steps)"
    );
    // The window really was mixed throughout, and both requests are
    // still mid-flight (steady state, not retirement).
    assert_eq!(engine.metrics.mixed_steps, mixed_before + 100);
    assert_eq!(engine.running_len(), 2);

    // Sanity: the run still completes correctly afterwards.
    let done = engine.run_until_idle().unwrap();
    assert_eq!(done.len(), 2);
    assert!(done.iter().all(|f| f.reason == fa3_split::coordinator::FinishReason::Length));
}
