//! Fleet-level integration tests for the cluster subsystem: TP identity,
//! shard validation, router invariants (session stickiness, least-loaded
//! admissibility), and the acceptance property that the sequence-aware
//! advantage widens as TP sharding shrinks per-shard head count.

use fa3_split::backend::AttnGeometry;
use fa3_split::cluster::{
    router, ClusterTopology, Fleet, FleetConfig, FleetReport, LeastLoaded, Replica, ReplicaSpec,
    RoundRobin, Router, SessionAffinity, TopologyError, TpConfig,
};
use fa3_split::coordinator::{
    BatcherConfig, BlockManagerConfig, Engine, EngineConfig, FinishedRequest,
};
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::planner::{DeviceProfile, PolicyRegistry};
use fa3_split::util::proptest_lite::{check, Domain};
use fa3_split::workload::ChatWorkload;

fn llama70b() -> AttnGeometry {
    AttnGeometry { h_q: 64, h_kv: 8, d: 128, max_seq: 1024 }
}

fn b1_engine_cfg() -> EngineConfig {
    EngineConfig { batcher: BatcherConfig::for_max_batch(1), ..Default::default() }
}

fn build_fleet(
    n: usize,
    tp: usize,
    router: Box<dyn Router>,
    policy: &str,
    engine: EngineConfig,
) -> Fleet {
    let topology = ClusterTopology::builder(llama70b())
        .tp(TpConfig::new(tp))
        .replicas(n, DeviceProfile::H100_SXM)
        .build()
        .unwrap();
    Fleet::new(topology, router, FleetConfig::default().policy(policy).engine(engine)).unwrap()
}

fn heavy_decode(seed: u64, n_requests: usize) -> ChatWorkload {
    // The shared boundary-bucket regime with 64-token outputs: prompts in
    // [385, 448], so every decode step of every request lands inside the
    // L_K=385..512 bucket and the sequence-aware advantage is fully
    // exposed wherever tiles < 4.
    ChatWorkload::boundary_bucket(seed, n_requests, 64)
}

// ---------------------------------------------------------------------
// TP identity: tp_degree = 1 planning is element-wise identical to the
// single-planner stack, and invalid head/TP combinations never build.
// ---------------------------------------------------------------------

#[test]
fn tp1_shard_planning_is_identity_property() {
    let topology = ClusterTopology::builder(llama70b())
        .tp(TpConfig::new(1))
        .replicas(1, DeviceProfile::H100_SXM)
        .build()
        .unwrap();
    assert_eq!(topology.shard_geometry(), llama70b());
    check(
        "tp1-plan-identity",
        &[Domain::new(1, 8), Domain::new(1, 4096)],
        |case| {
            let (batch, l_k) = (case[0] as usize, case[1] as usize);
            let sharded = topology.shard_shape(batch, l_k);
            let raw = DecodeShape::decode(batch, l_k, 64, 8, 128);
            if sharded != raw {
                return Err(format!("shard shape diverged: {sharded:?} vs {raw:?}"));
            }
            let mut fleet_planner = PolicyRegistry::builtin()
                .builder_for("sequence-aware", &DeviceProfile::H100_SXM)
                .unwrap()
                .build();
            let mut single = PolicyRegistry::builtin().planner("sequence-aware").unwrap();
            let a = fleet_planner.plan(&sharded);
            let b = single.plan(&raw);
            if a != b {
                return Err(format!("plan diverged at B={batch} L_K={l_k}: {a:?} vs {b:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn tp1_single_replica_fleet_matches_bare_engine() {
    let stream = heavy_decode(0xF1, 6).generate();

    let mut fleet =
        build_fleet(1, 1, Box::new(RoundRobin::new()), "sequence-aware", b1_engine_cfg());
    let report = fleet.run(&stream).unwrap();

    let planner = PolicyRegistry::builtin()
        .builder_for("sequence-aware", &DeviceProfile::H100_SXM)
        .unwrap()
        .build();
    let mut engine = Engine::builder(Box::new(fa3_split::backend::SimBackend::for_profile(
        &DeviceProfile::H100_SXM,
    )))
    .planner(planner)
    .geometry(llama70b())
    .config(b1_engine_cfg())
    .build()
    .unwrap();
    for g in &stream {
        engine.submit_at(g.request.clone(), g.arrival_offset_us).unwrap();
    }
    let bare = engine.run_until_idle().unwrap();

    let by_id = |mut v: Vec<FinishedRequest>| {
        v.sort_by_key(|f| f.id);
        v
    };
    let (a, b) = (by_id(report.finished.clone()), by_id(bare));
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "request {}", x.id);
        assert_eq!(x.reason, y.reason);
        assert_eq!(x.timing.first_token_us, y.timing.first_token_us);
        assert_eq!(x.timing.finished_us, y.timing.finished_us);
    }
    assert_eq!(
        report.replicas[0].tokens_generated,
        engine.metrics.tokens_generated,
        "fleet-of-one must be byte-identical serving"
    );
    assert_eq!(
        fleet.replicas()[0].metrics().split_histogram,
        engine.metrics.split_histogram
    );
}

#[test]
fn invalid_tp_divisibility_rejected_at_build() {
    check("tp-divisibility", &[Domain::new(0, 16)], |case| {
        let degree = case[0] as usize;
        let result = ClusterTopology::builder(llama70b())
            .tp(TpConfig::new(degree))
            .replicas(1, DeviceProfile::H100_SXM)
            .build();
        let should_build = degree >= 1 && 8 % degree == 0;
        match (should_build, result) {
            (true, Ok(topo)) => {
                if topo.shard_geometry().h_kv != 8 / degree {
                    return Err(format!("tp={degree}: wrong shard h_kv"));
                }
                Ok(())
            }
            (false, Err(TopologyError::IndivisibleHeads { .. }))
            | (false, Err(TopologyError::ZeroDegree)) => Ok(()),
            (expected, got) => {
                Err(format!("tp={degree}: expected buildable={expected}, got {got:?}"))
            }
        }
    });
}

// ---------------------------------------------------------------------
// Router invariants.
// ---------------------------------------------------------------------

#[test]
fn session_affinity_keeps_sessions_whole() {
    let mut fleet = build_fleet(
        4,
        8,
        Box::new(SessionAffinity::new()),
        "sequence-aware",
        EngineConfig::default(),
    );
    // Tight arrivals keep replicas visibly busy, so least-loaded first-turn
    // placement spreads sessions instead of tie-breaking to replica 0.
    let stream = ChatWorkload {
        mean_gap_us: 300,
        turns_per_session: 4,
        ..heavy_decode(0xF2, 24)
    }
    .generate();
    let report = fleet.run(&stream).unwrap();
    assert_eq!(report.finished.len(), 24, "every turn served");
    assert_eq!(report.rejected, 0);
    // THE affinity assertion: every request (and therefore every token)
    // of a session stayed on one replica.
    assert_eq!(report.affinity_violations(), 0);
    for session in 0..6u64 {
        let replicas: Vec<usize> = report
            .assignments
            .iter()
            .filter(|a| a.session == session)
            .map(|a| a.replica)
            .collect();
        assert_eq!(replicas.len(), 4, "4 turns routed for session {session}");
        assert!(
            replicas.windows(2).all(|w| w[0] == w[1]),
            "session {session} split across replicas: {replicas:?}"
        );
    }
    // Sessions actually spread over the fleet (stickiness ≠ single-replica
    // collapse).
    let used: std::collections::HashSet<usize> =
        report.assignments.iter().map(|a| a.replica).collect();
    assert!(used.len() > 1, "fleet-wide placement collapsed to {used:?}");
}

#[test]
fn least_loaded_never_routes_to_unadmittable_replica() {
    // Replica 1's KV budget (16 blocks x 16 tokens = 256) can never hold a
    // boundary-bucket request (385..512 prompt + 64 new); LeastLoaded must
    // send everything to replica 0 even though replica 0 is busier.
    let starved = EngineConfig {
        blocks: BlockManagerConfig { block_size: 16, num_blocks: 16, max_seq: 1024, ..Default::default() },
        ..Default::default()
    };
    let topology = ClusterTopology::builder(llama70b())
        .tp(TpConfig::new(8))
        .replica(ReplicaSpec::new(DeviceProfile::H100_SXM))
        .replica(ReplicaSpec::new(DeviceProfile::H100_SXM).engine(starved))
        .build()
        .unwrap();
    let mut fleet = Fleet::new(
        topology,
        Box::new(LeastLoaded::new()),
        FleetConfig::default().policy("sequence-aware"),
    )
    .unwrap();
    let report = fleet.run(&heavy_decode(0xF3, 10).generate()).unwrap();
    assert_eq!(report.finished.len(), 10);
    assert_eq!(report.rejected, 0, "nothing was refused at submission");
    assert!(
        report.assignments.iter().all(|a| a.replica == 0),
        "a request reached the starved replica: {:?}",
        report.assignments
    );
    assert_eq!(report.replicas[1].requests_assigned, 0);
}

#[test]
fn round_robin_balances_a_homogeneous_fleet() {
    let mut fleet =
        build_fleet(3, 8, Box::new(RoundRobin::new()), "sequence-aware", EngineConfig::default());
    let report = fleet.run(&heavy_decode(0xF4, 12).generate()).unwrap();
    let assigned: Vec<usize> = report.replicas.iter().map(|r| r.requests_assigned).collect();
    assert_eq!(assigned, vec![4, 4, 4]);
    assert_eq!(report.finished.len(), 12);
    // Aggregates are conserved across the per-replica split.
    let tokens: usize = report.replicas.iter().map(|r| r.tokens_generated).sum();
    assert_eq!(tokens, report.total_tokens);
    let finished: usize = report.replicas.iter().map(|r| r.requests_finished).sum();
    assert_eq!(finished, 12);
    assert!(report.imbalance() < 0.2, "imbalance {:.3}", report.imbalance());
    assert!(report.aggregate_tok_s > 0.0);
}

#[test]
fn heterogeneous_fleet_serves_with_per_device_planning() {
    let topology = ClusterTopology::builder(llama70b())
        .tp(TpConfig::new(8))
        .replica(ReplicaSpec::new(DeviceProfile::H100_SXM))
        .replica(ReplicaSpec::new(DeviceProfile::A100_SXM))
        .build()
        .unwrap();
    let mut fleet = Fleet::new(
        topology,
        Box::new(RoundRobin::new()),
        FleetConfig::default().policy("sequence-aware"),
    )
    .unwrap();
    let report = fleet.run(&heavy_decode(0xF5, 8).generate()).unwrap();
    assert_eq!(report.finished.len(), 8);
    assert_eq!(report.replicas[0].device, "H100-SXM5");
    assert_eq!(report.replicas[1].device, "A100-SXM4");
    for r in &report.replicas {
        assert!(r.mean_occupancy.unwrap() > 0.0, "replica {} has occupancy", r.index);
    }
    // The A100 has fewer SMs: the same launch occupies more of it.
    assert!(report.replicas[1].mean_occupancy.unwrap() > report.replicas[0].mean_occupancy.unwrap());
}

// ---------------------------------------------------------------------
// The acceptance property: the sequence-aware advantage widens as TP
// sharding shrinks per-shard head count (mirrors benches/cluster_scale).
// ---------------------------------------------------------------------

#[test]
fn sequence_aware_advantage_widens_with_tp_degree() {
    let run = |tp: usize, policy: &str| -> FleetReport {
        let mut fleet =
            build_fleet(2, tp, Box::new(RoundRobin::new()), policy, b1_engine_cfg());
        fleet.run(&heavy_decode(0xF6, 8).generate()).unwrap()
    };
    let mut advantages = Vec::new();
    for tp in [1usize, 2, 4, 8] {
        let std = run(tp, "standard");
        let seq = run(tp, "sequence-aware");
        let (a, b) = (
            std.tpot.as_ref().expect("tpot").mean,
            seq.tpot.as_ref().expect("tpot").mean,
        );
        assert!(b > 0.0);
        advantages.push((tp, a / b, std.mean_occupancy(), seq.mean_occupancy()));
    }
    // Never a regression; monotone non-decreasing; strictly open at tp=8.
    for &(tp, adv, _, _) in &advantages {
        assert!(adv >= 0.999, "tp={tp} regressed: {adv:.4}");
    }
    for w in advantages.windows(2) {
        assert!(
            w[1].1 >= w[0].1 - 1e-6,
            "advantage shrank from tp={} ({:.4}) to tp={} ({:.4})",
            w[0].0,
            w[0].1,
            w[1].0,
            w[1].1
        );
    }
    let (_, adv8, occ8_std, occ8_seq) = advantages[3];
    let (_, adv1, occ1_std, _) = advantages[0];
    assert!(adv8 > 1.05, "tp=8 advantage too small: {adv8:.4}");
    assert!(adv8 > adv1 + 0.03, "no widening: tp1 {adv1:.4} vs tp8 {adv8:.4}");
    // Occupancy: sharding starves the standard policy; the override
    // recovers a chunk at tp=8.
    assert!(occ8_std < occ1_std, "standard occupancy should collapse with tp");
    assert!(occ8_seq > occ8_std, "sequence-aware should lift tp=8 occupancy");
}

#[test]
fn per_replica_streams_are_reproducible_and_distinct() {
    // Replica-local saturation driving (no router): each replica consumes
    // its own derived stream. Same base seed ⇒ byte-identical outcomes
    // run-to-run; different replica indices ⇒ distinct traffic.
    let run_once = || {
        let topology = ClusterTopology::builder(llama70b())
            .tp(TpConfig::new(8))
            .replicas(2, DeviceProfile::H100_SXM)
            .build()
            .unwrap();
        let base = heavy_decode(0xF7, 6);
        let mut outcomes = Vec::new();
        for (i, spec) in topology.replicas().iter().enumerate() {
            let planner = PolicyRegistry::builtin()
                .builder_for("sequence-aware", &spec.device)
                .unwrap()
                .build();
            let mut replica =
                Replica::new(i, spec, topology.shard_geometry(), planner, &EngineConfig::default())
                    .unwrap();
            for g in base.stream_for_replica(i).generate() {
                replica.submit_at(g.request, g.arrival_offset_us).unwrap();
            }
            let mut done = replica.run_until_idle().unwrap();
            done.sort_by_key(|f| f.id);
            outcomes.push(
                done.iter()
                    .map(|f| (f.prompt_len, f.tokens.len(), f.timing.finished_us))
                    .collect::<Vec<_>>(),
            );
        }
        outcomes
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(a, b, "same base seed ⇒ identical per-replica outcomes");
    assert_ne!(a[0], a[1], "replica indices draw distinct streams");
}

// ---------------------------------------------------------------------
// Router name registry drives the CLI surface.
// ---------------------------------------------------------------------

#[test]
fn router_registry_covers_all_names() {
    for name in fa3_split::cluster::ROUTER_NAMES {
        let r = router::by_name(name).unwrap();
        assert_eq!(r.name(), name);
        assert!(router::help_line().contains(name));
    }
    assert!(router::by_name("does-not-exist").is_none());
}
