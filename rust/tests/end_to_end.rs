//! End-to-end: the full three-layer stack — JAX/Pallas AOT artifacts,
//! PJRT runtime, rust coordinator — serving real requests.
//!
//! The key cross-layer property: the split policy changes ONLY scheduling.
//! Served generations must be token-identical under the standard and the
//! sequence-aware policy, because the s=1 and s=3 artifacts compute the
//! same attention (validated per-kernel in L1 tests; validated here at
//! the full serving level). Requires `make artifacts` (skips otherwise).

use std::path::PathBuf;
use std::sync::Arc;

use fa3_split::backend::PjrtBackend;
use fa3_split::coordinator::{Engine, EngineConfig, FinishReason, Request};
use fa3_split::planner::Planner;
use fa3_split::runtime::Registry;
use fa3_split::workload::ChatWorkload;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn pjrt_engine(registry: Arc<Registry>, planner: Planner) -> Engine {
    let cfg = EngineConfig::default();
    let backend = PjrtBackend::new(registry, cfg.batcher.max_batch).unwrap();
    Engine::builder(Box::new(backend)).planner(planner).config(cfg).build().unwrap()
}

fn serve(
    registry: Arc<Registry>,
    planner: Planner,
    requests: &[Request],
) -> Vec<(u64, Vec<i32>)> {
    let mut engine = pjrt_engine(registry, planner);
    for r in requests {
        engine.submit(r.clone()).unwrap();
    }
    let mut done = engine.run_until_idle().unwrap();
    assert_eq!(done.len(), requests.len());
    for f in &done {
        assert_eq!(f.reason, FinishReason::Length);
        assert!(f.tokens.iter().all(|&t| t >= 0), "invalid token id");
    }
    done.sort_by_key(|f| f.id);
    done.into_iter().map(|f| (f.id, f.tokens)).collect()
}

#[test]
fn served_generations_identical_across_policies() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let registry = Arc::new(Registry::open(&dir).unwrap());
    if registry.manifest.model.is_none() {
        eprintln!("SKIP: no model artifacts");
        return;
    }

    // Short prompts, few tokens: keep CPU time modest while still crossing
    // prefill + batched decode + retirement.
    let workload = ChatWorkload {
        seed: 11,
        n_requests: 3,
        prompt_median: 24,
        prompt_cap: 64,
        output_mean: 6,
        output_cap: 6,
        ..Default::default()
    };
    let requests: Vec<Request> = workload
        .generate()
        .into_iter()
        .map(|g| {
            let mut r = g.request;
            r.max_new_tokens = 6;
            r
        })
        .collect();

    let out_std = serve(registry.clone(), Planner::standard(), &requests);
    let out_pat = serve(registry.clone(), Planner::sequence_aware(), &requests);
    assert_eq!(
        out_std, out_pat,
        "split policy changed generated tokens — scheduling leaked into math"
    );

    // Determinism: a re-run reproduces bit-identical generations.
    let out_again = serve(registry, Planner::standard(), &requests);
    assert_eq!(out_std, out_again);
}

#[test]
fn serving_batches_multiple_requests() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let registry = Arc::new(Registry::open(&dir).unwrap());
    if registry.manifest.model.is_none() {
        return;
    }
    let mut engine = pjrt_engine(registry, Planner::sequence_aware());
    for id in 0..3 {
        engine.submit(Request::new(id, vec![(id as i32) + 5; 8], 4)).unwrap();
    }
    let done = engine.run_until_idle().unwrap();
    assert_eq!(done.len(), 3);
    // Batched: 4 decode rounds, not 12.
    assert!(engine.metrics.decode_steps <= 6, "decode_steps={}", engine.metrics.decode_steps);
    assert_eq!(engine.metrics.tokens_generated, 12);
    // Each sequence decoded its own tokens (slots don't leak): different
    // prompts should (generically) give different generations.
    let distinct: std::collections::HashSet<&Vec<i32>> =
        done.iter().map(|f| &f.tokens).collect();
    assert!(distinct.len() > 1, "all generations identical — slot mixing suspected");
}
