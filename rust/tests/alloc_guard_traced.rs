//! The zero-allocation guarantee with the flight recorder **enabled**
//! (DESIGN.md §Observability): tracing a warmed-up decode window must not
//! add a single heap allocation per step.
//!
//! The recorder's storage is an overwrite-oldest [`EventRing`] whose one
//! allocation happens at construction; every `record` is a store plus two
//! index updates, and the keyed occupancy histograms observe into buckets
//! fixed at registration. The ring here is deliberately sized *smaller*
//! than the event volume of the measured window, so the wrap/overwrite
//! path — the steady state of any long traced run — is what the counter
//! measures, not just the fill path.
//!
//! Single `#[test]` file: the allocation counter is process-global (same
//! constraint as `tests/alloc_guard.rs` and `tests/alloc_guard_chunked.rs`).
//!
//! [`EventRing`]: fa3_split::obs::EventRing

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{Engine, EngineConfig, Request};
use fa3_split::planner::Planner;
use fa3_split::util::alloc_counter::{self, CountingAllocator};

#[global_allocator]
static COUNTER: CountingAllocator = CountingAllocator;

#[test]
fn traced_decode_step_allocates_nothing_after_warmup() {
    // 256 events < 100 steps x 3 events/step (StepComposed + PlanDecision
    // + WaveCost): the ring must wrap while the counter is watching.
    let mut engine = Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 2048 })
        .config(EngineConfig { trace_capacity: 256, ..Default::default() })
        .build()
        .unwrap();
    assert!(engine.recorder().enabled());
    drop(engine.submit(Request::new(1, vec![1; 350], 400)).unwrap());
    drop(engine.submit(Request::new(2, vec![1; 350], 400)).unwrap());

    // Warmup: admission, prefill, and enough decode steps to size every
    // scratch buffer (same budget as the untraced decode guard).
    for _ in 0..24 {
        engine.step().unwrap();
    }
    assert!(engine.waiting_len() == 0 && engine.running_len() == 2, "warmup should settle");
    engine.metrics.reserve_capacity(256, 16);

    let events_before = engine.recorder().len();
    let before = alloc_counter::total_allocations();
    for _ in 0..100 {
        engine.step().unwrap();
    }
    let allocated = alloc_counter::total_allocations() - before;

    assert_eq!(
        allocated, 0,
        "traced steady-state decode steps must not allocate (got {allocated} over 100 steps)"
    );
    // The window really recorded: the ring filled from warmup's residue,
    // wrapped, and kept only the newest events.
    assert!(events_before > 0, "warmup should leave events in the ring");
    assert_eq!(engine.recorder().len(), 256, "ring should be full");
    assert!(
        engine.recorder().dropped() > 0,
        "window must exercise the overwrite path, not just the fill path"
    );
    // Keyed occupancy histograms observed without allocating.
    assert!(engine.metrics.decode_occupancy_samples() > 100);
    assert_eq!(engine.running_len(), 2);

    // Sanity: the traced run still completes correctly afterwards.
    let done = engine.run_until_idle().unwrap();
    assert_eq!(done.len(), 2);
    assert!(done.iter().all(|f| f.tokens.len() == 400));
}
