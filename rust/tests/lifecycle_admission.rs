//! Property-style tests (util::proptest_lite) for the request-lifecycle +
//! admission-controller invariants:
//!
//! * the KV-block budget is never exceeded, under any interleaving of
//!   submissions, cancellations, and steps,
//! * FIFO within a priority class (and strict priority across classes),
//! * cancelled requests free their blocks (and KV rows) promptly,
//! * bounded queues reject with an explicit `Backpressure` outcome,
//! * deadlines cut requests short with `DeadlineExceeded`,
//! * the streaming handle sees exactly the tokens the result carries.

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{
    BatcherConfig, BlockManagerConfig, Engine, EngineConfig, FinishReason, Priority, Request,
    StreamEvent, SubmitError, SubmitOptions,
};
use fa3_split::planner::Planner;
use fa3_split::util::prng::Rng;
use fa3_split::util::proptest_lite::{check, Config, Domain};
use fa3_split::workload::ChatWorkload;

fn engine(max_batch: usize, num_blocks: usize, queue_capacity: usize) -> Engine {
    let buckets: Vec<usize> = [1, 2, 4, 8].into_iter().filter(|&b| b <= max_batch).collect();
    let mut cfg = EngineConfig {
        batcher: BatcherConfig { max_batch: *buckets.last().unwrap(), batch_buckets: buckets },
        blocks: BlockManagerConfig { block_size: 16, num_blocks, max_seq: 1024, ..Default::default() },
        ..Default::default()
    };
    cfg.admission.queue_capacity = queue_capacity;
    Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(cfg)
        .build()
        .unwrap()
}

#[test]
fn kv_budget_never_exceeded_under_random_lifecycles() {
    // Random interleavings of submit / cancel / step: block accounting
    // must balance and stay within budget at EVERY step boundary.
    check(
        "kv-budget",
        &[Domain::new(2, 16), Domain::new(4, 64), Domain::new(0, u64::MAX)],
        |case| {
            let max_batch = case[0] as usize; // engine() snaps to the bucket grid
            let num_blocks = case[1] as usize;
            let mut rng = Rng::new(case[2]);
            let mut e = engine(max_batch, num_blocks, 64);
            let budget = num_blocks;
            let mut handles = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..120 {
                match rng.range(0, 2) {
                    0 => {
                        let prompt = rng.range(1, 200);
                        let max_new = rng.range(1, 64);
                        if let Ok(h) = e.submit(Request::new(next_id, vec![1; prompt], max_new)) {
                            handles.push(h);
                        }
                        next_id += 1;
                    }
                    1 => {
                        if !handles.is_empty() {
                            let idx = rng.range(0, handles.len() - 1);
                            handles[idx].cancel();
                        }
                    }
                    _ => {
                        e.step().map_err(|err| format!("step: {err:#}"))?;
                    }
                }
                let blocks = e.block_manager();
                blocks.check_invariants().map_err(|err| format!("{err:#}"))?;
                if blocks.used_blocks() > budget {
                    return Err(format!(
                        "{} blocks in use, budget {}",
                        blocks.used_blocks(),
                        budget
                    ));
                }
            }
            let _ = e.run_until_idle().map_err(|err| format!("drain: {err:#}"))?;
            if e.block_manager().num_seqs() != 0 {
                return Err("blocks leaked after drain".into());
            }
            Ok(())
        },
    );
}

#[test]
fn fifo_within_each_priority_class() {
    // Single-slot engine: completion order == admission order. Restricted
    // to any one priority class, that order must equal submission order,
    // whatever the interleaving of classes.
    check(
        "class-fifo",
        &[Domain::new(2, 20), Domain::new(0, u64::MAX)],
        |case| {
            let n = case[0] as usize;
            let mut rng = Rng::new(case[1]);
            let mut e = engine(1, 256, 64);
            let mut class_of = Vec::new();
            for id in 0..n as u64 {
                let priority = match rng.range(0, 2) {
                    0 => Priority::Interactive,
                    1 => Priority::Standard,
                    _ => Priority::Batch,
                };
                class_of.push(priority);
                e.submit_with(
                    Request::new(id, vec![1; 10], 3),
                    SubmitOptions::default().priority(priority),
                )
                .map_err(|err| format!("refused: {err}"))?;
            }
            let done = e.run_until_idle().map_err(|err| format!("{err:#}"))?;
            if done.len() != n {
                return Err(format!("{} of {n} finished", done.len()));
            }
            for class in Priority::all() {
                let completed: Vec<u64> = done
                    .iter()
                    .filter(|f| class_of[f.id as usize] == class)
                    .map(|f| f.id)
                    .collect();
                let mut sorted = completed.clone();
                sorted.sort_unstable();
                if completed != sorted {
                    return Err(format!("class {class:?} completed out of order: {completed:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cancelled_requests_free_blocks() {
    // Cancel a random subset mid-flight: every cancelled request must
    // release its blocks, every survivor must still finish Length, and the
    // manager must end empty.
    check(
        "cancel-frees-blocks",
        &[Domain::new(2, 12), Domain::new(1, 10), Domain::new(0, u64::MAX)],
        |case| {
            let n = case[0] as usize;
            let steps_before_cancel = case[1] as usize;
            let mut rng = Rng::new(case[2]);
            let mut e = engine(4, 256, 64);
            let mut handles = Vec::new();
            for id in 0..n as u64 {
                handles.push(
                    e.submit(Request::new(id, vec![1; 50], 200))
                        .map_err(|err| format!("refused: {err}"))?,
                );
            }
            for _ in 0..steps_before_cancel {
                e.step().map_err(|err| format!("{err:#}"))?;
            }
            let mut cancelled_ids = Vec::new();
            for (id, h) in handles.iter().enumerate() {
                if rng.chance(0.5) {
                    h.cancel();
                    cancelled_ids.push(id as u64);
                }
            }
            let done = e.run_until_idle().map_err(|err| format!("{err:#}"))?;
            if done.len() != n {
                return Err(format!("{} of {n} finished", done.len()));
            }
            for f in &done {
                let was_cancelled = cancelled_ids.contains(&f.id);
                match (was_cancelled, f.reason) {
                    (true, FinishReason::Cancelled) => {}
                    // A cancel can race natural completion: Length is legal
                    // for a cancelled id, but not the reverse.
                    (true, FinishReason::Length) => {}
                    (false, FinishReason::Length) => {}
                    (c, r) => return Err(format!("req {} cancelled={c} reason={r:?}", f.id)),
                }
            }
            e.block_manager().check_invariants().map_err(|err| format!("{err:#}"))?;
            if e.block_manager().num_seqs() != 0 {
                return Err("cancelled requests leaked blocks".into());
            }
            Ok(())
        },
    );
}

#[test]
fn bounded_queue_backpressure_is_exact() {
    // With a single slot and tiny queues, exactly (capacity + running)
    // submissions can be in flight; the rest must come back Backpressure
    // and the admitted ones must all finish.
    check(
        "backpressure",
        &[Domain::new(1, 6), Domain::new(2, 24)],
        |case| {
            let capacity = case[0] as usize;
            let n = case[1] as usize;
            let mut e = engine(1, 256, capacity);
            let mut accepted = 0usize;
            let mut rejected = 0usize;
            for id in 0..n as u64 {
                match e.submit(Request::new(id, vec![1; 10], 2)) {
                    Ok(_) => accepted += 1,
                    Err(SubmitError::Backpressure(bp)) => {
                        if bp.capacity != capacity {
                            return Err(format!("capacity {} != {capacity}", bp.capacity));
                        }
                        rejected += 1;
                    }
                    Err(other) => return Err(format!("unexpected refusal: {other}")),
                }
            }
            if accepted != n.min(capacity) {
                return Err(format!("accepted {accepted}, expected {}", n.min(capacity)));
            }
            if accepted + rejected != n {
                return Err("accounting broken".into());
            }
            let done = e.run_until_idle().map_err(|err| format!("{err:#}"))?;
            if done.len() != accepted {
                return Err(format!("{} finished, {accepted} accepted", done.len()));
            }
            if e.metrics.rejected_backpressure != rejected {
                return Err("metrics disagree with rejections".into());
            }
            Ok(())
        },
    );
}

#[test]
fn deadlines_cut_requests_short_exactly_once_past_the_clock() {
    check(
        "deadline",
        &[Domain::new(1, 40), Domain::new(0, u64::MAX)],
        |case| {
            let deadline_us = case[0] * 250; // 250 µs .. 10 ms, virtual
            let mut rng = Rng::new(case[1]);
            let mut e = engine(2, 256, 64);
            let n = 4u64;
            for id in 0..n {
                let max_new = rng.range(4, 400);
                e.submit_with(
                    Request::new(id, vec![1; 50], max_new),
                    SubmitOptions::default().deadline_us(deadline_us),
                )
                .map_err(|err| format!("refused: {err}"))?;
            }
            let done = e.run_until_idle().map_err(|err| format!("{err:#}"))?;
            if done.len() != n as usize {
                return Err(format!("{} of {n} finished", done.len()));
            }
            for f in &done {
                match f.reason {
                    FinishReason::Length => {
                        // Finished before its deadline hit. Nothing to check:
                        // completion timestamps are step-quantized.
                    }
                    FinishReason::DeadlineExceeded => {
                        if f.timing.finished_us < deadline_us {
                            return Err(format!(
                                "req {} reaped at {} before deadline {deadline_us}",
                                f.id, f.timing.finished_us
                            ));
                        }
                    }
                    other => return Err(format!("req {}: unexpected {other:?}", f.id)),
                }
            }
            e.block_manager().check_invariants().map_err(|err| format!("{err:#}"))?;
            Ok(())
        },
    );
}

#[test]
fn streams_carry_exactly_the_resulting_tokens() {
    // For every request in a random workload, the handle's token stream
    // must equal the tokens in its FinishedRequest, in order, ending with
    // the terminal event.
    check(
        "stream-equivalence",
        &[Domain::new(1, 16), Domain::new(0, u64::MAX)],
        |case| {
            let n = case[0] as usize;
            let workload = ChatWorkload {
                seed: case[1],
                n_requests: n,
                prompt_median: 80,
                output_mean: 10,
                output_cap: 24,
                ..Default::default()
            };
            let mut e = engine(4, 512, 64);
            let mut handles = Vec::new();
            for g in workload.generate() {
                handles.push(e.submit(g.request).map_err(|err| format!("refused: {err}"))?);
            }
            let mut done = e.run_until_idle().map_err(|err| format!("{err:#}"))?;
            done.sort_by_key(|f| f.id);
            for (f, h) in done.iter().zip(handles.iter()) {
                let mut streamed = Vec::new();
                let mut finished = None;
                while let Some(ev) = h.try_event() {
                    match ev {
                        StreamEvent::Token { token, index, .. } => {
                            if index != streamed.len() {
                                return Err(format!("req {}: token index gap", f.id));
                            }
                            streamed.push(token);
                        }
                        StreamEvent::Finished(fin) => finished = Some(fin),
                        StreamEvent::Rejected(err) => {
                            return Err(format!("req {}: spurious rejection {err}", f.id))
                        }
                    }
                }
                if streamed != f.tokens {
                    return Err(format!("req {}: stream != result tokens", f.id));
                }
                let fin = finished.ok_or_else(|| format!("req {}: no terminal event", f.id))?;
                if fin.tokens != f.tokens || fin.reason != f.reason {
                    return Err(format!("req {}: terminal event disagrees", f.id));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn strict_priority_serves_interactive_first() {
    // Not a property test: a deterministic check that with everything
    // submitted up front, Interactive requests complete before Standard
    // before Batch on a single slot.
    let mut e = engine(1, 256, 64);
    for (id, priority) in [
        (0u64, Priority::Batch),
        (1, Priority::Standard),
        (2, Priority::Interactive),
        (3, Priority::Batch),
        (4, Priority::Interactive),
    ] {
        e.submit_with(Request::new(id, vec![1; 10], 2), SubmitOptions::default().priority(priority))
            .unwrap();
    }
    let done = e.run_until_idle().unwrap();
    let order: Vec<u64> = done.iter().map(|f| f.id).collect();
    assert_eq!(order, vec![2, 4, 1, 0, 3]);
}

#[test]
fn queued_request_past_deadline_is_reaped_before_it_ever_runs() {
    // Regression (PR 9): the step loop reaps expired deadlines BEFORE
    // the admission pass, so a request whose deadline elapses while it
    // waits in the queue must finish `DeadlineExceeded` without ever
    // occupying a slot — even when a slot frees up on the very step the
    // reap happens. A single long-running request holds the one slot
    // well past the queued request's deadline; the zeroed
    // scheduled/first-token timestamps prove the victim never ran.
    let mut e = engine(1, 256, 64);
    e.submit(Request::new(0, vec![1; 64], 200)).unwrap();
    // ~200 decode steps at 12-30 µs each: the slot stays busy for
    // thousands of µs, far past the 500 µs deadline below.
    e.submit_with(
        Request::new(1, vec![2; 32], 8),
        SubmitOptions::default().deadline_us(500),
    )
    .unwrap();
    let mut done = e.run_until_idle().unwrap();
    done.sort_by_key(|f| f.id);
    assert_eq!(done.len(), 2);

    let held = &done[0];
    assert_eq!(held.reason, FinishReason::Length);
    assert_eq!(held.tokens.len(), 200, "the slot-holder must be untouched by the reap");

    let reaped = &done[1];
    assert_eq!(reaped.reason, FinishReason::DeadlineExceeded);
    assert!(reaped.tokens.is_empty(), "an expired queued request must not generate");
    assert_eq!(reaped.timing.scheduled_us, 0, "reaped before admit: never scheduled");
    assert_eq!(reaped.timing.first_token_us, 0, "reaped before admit: no first token");
    assert!(
        reaped.timing.finished_us >= 500,
        "reaped at {} µs, before its own 500 µs deadline",
        reaped.timing.finished_us
    );
    // And it finished long before the slot-holder ever released the
    // slot — the reap didn't wait for capacity.
    assert!(
        reaped.timing.finished_us < held.timing.finished_us,
        "queued deadline ({} µs) should fire while the slot is still held (released {} µs)",
        reaped.timing.finished_us,
        held.timing.finished_us
    );
    assert_eq!(e.metrics.deadline_misses, 1);
}

#[test]
fn proptest_config_is_replayable() {
    // The lifecycle suites honor FA3_PROPTEST_SEED (documented replay
    // path); just assert the plumbing exists.
    let cfg = Config::default();
    assert!(cfg.cases >= 1);
}
