//! Integration: the rust runtime executes the real AOT artifacts.
//!
//! Requires `make artifacts` to have been run (skipped with a message
//! otherwise, so `cargo test` stays green on a fresh checkout).

use fa3_split::runtime::{HostTensor, Registry};
use fa3_split::util::prng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn rand_f32(rng: &mut Rng, shape: &[usize]) -> HostTensor {
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    HostTensor::f32(shape, data).unwrap()
}

/// Host reference decode attention (mirrors python/compile/kernels/ref.py).
fn ref_attention(
    q: &HostTensor,
    k: &HostTensor,
    v: &HostTensor,
    kv_lens: &[i32],
) -> Vec<f32> {
    let (b, h_q, d) = (q.shape()[0], q.shape()[1], q.shape()[2]);
    let (l_k, h_kv) = (k.shape()[1], k.shape()[2]);
    let g = h_q / h_kv;
    let scale = 1.0 / (d as f32).sqrt();
    let qd = q.as_f32().unwrap();
    let kd = k.as_f32().unwrap();
    let vd = v.as_f32().unwrap();
    let mut out = vec![0f32; b * h_q * d];
    for bi in 0..b {
        for hq in 0..h_q {
            let hk = hq / g;
            let len = kv_lens[bi] as usize;
            let qv = &qd[(bi * h_q + hq) * d..(bi * h_q + hq + 1) * d];
            let mut scores = vec![0f32; len];
            for t in 0..len {
                let kv = &kd[((bi * l_k + t) * h_kv + hk) * d..((bi * l_k + t) * h_kv + hk) * d + d];
                scores[t] = qv.iter().zip(kv).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
            let denom: f32 = exps.iter().sum();
            for t in 0..len {
                let w = exps[t] / denom;
                let vv = &vd[((bi * l_k + t) * h_kv + hk) * d..((bi * l_k + t) * h_kv + hk) * d + d];
                for di in 0..d {
                    out[(bi * h_q + hq) * d + di] += w * vv[di];
                }
            }
        }
    }
    out
}

#[test]
fn kernel_artifact_matches_host_reference() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let reg = Registry::open(&dir).unwrap();
    let mut rng = Rng::new(42);

    // The paper's winning shape: B=1, L_K=512, H_KV=1, s=3, vs s=1 —
    // both must agree with the host oracle and with each other.
    let mut outputs = Vec::new();
    let q = rand_f32(&mut rng, &[1, 8, 128]);
    let k = rand_f32(&mut rng, &[1, 512, 1, 128]);
    let v = rand_f32(&mut rng, &[1, 512, 1, 128]);
    let lens = HostTensor::s32(&[1], vec![512]).unwrap();
    for s in [1usize, 3] {
        let entry = reg
            .manifest
            .find_kernel(1, 512, 1, s)
            .expect("kernel artifact missing — rebuild artifacts");
        let exe = reg.executor_for(entry).unwrap();
        let out = exe
            .execute(&[q.clone(), k.clone(), v.clone(), lens.clone()])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[1, 8, 128]);
        outputs.push(out[0].as_f32().unwrap().to_vec());
    }
    // Split invariance on the real execution path.
    for (a, b) in outputs[0].iter().zip(&outputs[1]) {
        assert!((a - b).abs() < 1e-4, "split changed the math: {a} vs {b}");
    }
    // Against the host oracle.
    let expect = ref_attention(&q, &k, &v, &[512]);
    for (got, want) in outputs[1].iter().zip(&expect) {
        assert!((got - want).abs() < 1e-3, "kernel vs oracle: {got} vs {want}");
    }
}

#[test]
fn kernel_artifact_respects_kv_lens() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let reg = Registry::open(&dir).unwrap();
    let mut rng = Rng::new(7);
    let q = rand_f32(&mut rng, &[1, 8, 128]);
    let k = rand_f32(&mut rng, &[1, 512, 1, 128]);
    let v = rand_f32(&mut rng, &[1, 512, 1, 128]);
    let entry = reg.manifest.find_kernel(1, 512, 1, 3).unwrap();
    let exe = reg.executor_for(entry).unwrap();
    let lens = HostTensor::s32(&[1], vec![200]).unwrap();
    let out = exe.execute(&[q.clone(), k.clone(), v.clone(), lens]).unwrap();
    let expect = ref_attention(&q, &k, &v, &[200]);
    for (got, want) in out[0].as_f32().unwrap().iter().zip(&expect) {
        assert!((got - want).abs() < 1e-3);
    }
}

#[test]
fn executor_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let reg = Registry::open(&dir).unwrap();
    let entry = reg.manifest.find_kernel(1, 512, 1, 1).unwrap();
    let exe = reg.executor_for(entry).unwrap();
    let bad = HostTensor::zeros_f32(&[1, 8, 64]); // wrong D
    let k = HostTensor::zeros_f32(&[1, 512, 1, 128]);
    let v = HostTensor::zeros_f32(&[1, 512, 1, 128]);
    let lens = HostTensor::s32(&[1], vec![512]).unwrap();
    assert!(exe.execute(&[bad, k, v, lens]).is_err());
    // Wrong arity.
    assert!(exe.execute(&[]).is_err());
}

#[test]
fn model_decode_step_runs_and_chains() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let reg = Registry::open(&dir).unwrap();
    let Some(model) = reg.manifest.model.clone() else {
        eprintln!("SKIP: no model block in manifest");
        return;
    };
    let cfg = &model.config;
    let entry = reg.manifest.find_decode_bucket(1, 1).expect("decode bucket b1 s1");
    let b = entry.meta.batch.unwrap();
    let cache_shape = [cfg.n_layers, b, cfg.max_seq, cfg.n_heads_kv, cfg.head_dim];

    let tokens = HostTensor::s32(&[b], vec![1; b]).unwrap();
    let positions = HostTensor::s32(&[b], vec![0; b]).unwrap();
    let kv_k = HostTensor::zeros_f32(&cache_shape);
    let kv_v = HostTensor::zeros_f32(&cache_shape);

    let out = reg
        .execute_model(&entry.name, &[tokens, positions, kv_k, kv_v])
        .unwrap();
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].shape(), &[b, cfg.vocab]);
    let logits = out[0].as_f32().unwrap();
    assert!(logits.iter().all(|x| x.is_finite()), "non-finite logits");

    // Chain a second step on the updated caches: greedy-decode token.
    let next: i32 = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32;
    let tokens2 = HostTensor::s32(&[b], vec![next; b]).unwrap();
    let positions2 = HostTensor::s32(&[b], vec![1; b]).unwrap();
    let out2 = reg
        .execute_model(&entry.name, &[tokens2, positions2, out[1].clone(), out[2].clone()])
        .unwrap();
    assert!(out2[0].as_f32().unwrap().iter().all(|x| x.is_finite()));

    // Split invariance at the model level on the real path: the s=3
    // artifact must produce identical logits for identical state.
    if let Some(entry_s3) = reg.manifest.find_decode_bucket(1, 3) {
        let tokens = HostTensor::s32(&[b], vec![1; b]).unwrap();
        let positions = HostTensor::s32(&[b], vec![0; b]).unwrap();
        let kv_k = HostTensor::zeros_f32(&cache_shape);
        let kv_v = HostTensor::zeros_f32(&cache_shape);
        let out_s3 = reg
            .execute_model(&entry_s3.name, &[tokens, positions, kv_k, kv_v])
            .unwrap();
        for (a, c) in out[0].as_f32().unwrap().iter().zip(out_s3[0].as_f32().unwrap()) {
            assert!((a - c).abs() < 1e-3, "decode split changed logits: {a} vs {c}");
        }
    }
}

#[test]
fn prefill_then_decode_consistency() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    };
    let reg = Registry::open(&dir).unwrap();
    let Some(model) = reg.manifest.model.clone() else {
        return;
    };
    let cfg = &model.config;
    let Some(prefill) = reg.manifest.find_prefill_bucket(1, 8) else {
        eprintln!("SKIP: no prefill bucket");
        return;
    };
    let b = prefill.meta.batch.unwrap();
    let p_len = prefill.meta.prompt_len.unwrap();
    let cache_shape = [cfg.n_layers, b, cfg.max_seq, cfg.n_heads_kv, cfg.head_dim];

    let mut prompt = vec![0i32; b * p_len];
    let mut rng = Rng::new(3);
    let true_len = 8usize;
    for r in 0..b {
        for t in 0..true_len {
            prompt[r * p_len + t] = rng.range(0, cfg.vocab - 1) as i32;
        }
    }
    let tokens = HostTensor::s32(&[b, p_len], prompt.clone()).unwrap();
    let lens = HostTensor::s32(&[b], vec![true_len as i32; b]).unwrap();
    let out_p = reg
        .execute_model(
            &prefill.name,
            &[tokens, lens, HostTensor::zeros_f32(&cache_shape), HostTensor::zeros_f32(&cache_shape)],
        )
        .unwrap();

    // Decode the same prompt token-by-token through the decode bucket of the
    // same batch size; final logits must agree with prefill's.
    let decode = reg
        .manifest
        .entries
        .iter()
        .find(|e| {
            e.kind == fa3_split::runtime::ArtifactKind::Decode
                && e.meta.batch == Some(b)
                && e.meta.num_splits == Some(1)
        })
        .expect("matching decode bucket");
    let mut kv_k = HostTensor::zeros_f32(&cache_shape);
    let mut kv_v = HostTensor::zeros_f32(&cache_shape);
    let mut logits = Vec::new();
    for t in 0..true_len {
        let toks: Vec<i32> = (0..b).map(|r| prompt[r * p_len + t]).collect();
        let out = reg
            .execute_model(
                &decode.name,
                &[
                    HostTensor::s32(&[b], toks).unwrap(),
                    HostTensor::s32(&[b], vec![t as i32; b]).unwrap(),
                    kv_k,
                    kv_v,
                ],
            )
            .unwrap();
        logits = out[0].as_f32().unwrap().to_vec();
        kv_k = out[1].clone();
        kv_v = out[2].clone();
    }
    for (a, c) in out_p[0].as_f32().unwrap().iter().zip(&logits) {
        assert!((a - c).abs() < 2e-2, "prefill vs decode-loop logits: {a} vs {c}");
    }
}
