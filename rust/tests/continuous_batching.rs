//! Property suite for the continuous-batching step composer and its
//! engine integration (DESIGN.md §Continuous batching).
//!
//! The composer invariants under test, numbered as in
//! `src/schedule/mod.rs`:
//!
//! 2. chunk spans tile each prompt exactly (contiguous, non-overlapping,
//!    ending at the prompt length, first span skipping cached prefix but
//!    never the final token);
//! 3. the token budget bounds every composed step, with decode rows
//!    admitted before any chunk;
//! 1. chunked execution is semantically identical to monolithic prefill
//!    (same token streams, same finish reasons) — chunking moves *when*
//!    prompt tokens are ingested, never what gets computed;
//! plus the engine-level guarantees that per-step admission stays
//! FIFO within a priority class, KV block accounting survives every
//! mid-chunk step, and cancelling a request mid-prefill releases every
//! block it held.

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{
    BatcherConfig, Engine, EngineConfig, Priority, Request, SubmitOptions,
};
use fa3_split::planner::Planner;
use fa3_split::schedule::{MixedStepPlan, ScheduleConfig, SlotView, StepComposer, TokenBudget};
use fa3_split::util::proptest_lite::{check, check_with, Config, Domain};

const BUCKETS: &[usize] = &[1, 2, 4];

fn engine_with(schedule: ScheduleConfig, max_batch: usize) -> Engine {
    Engine::builder(Box::new(SimBackend::h100()))
        .planner(Planner::sequence_aware())
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .config(EngineConfig {
            batcher: BatcherConfig::for_max_batch(max_batch),
            schedule,
            ..Default::default()
        })
        .build()
        .unwrap()
}

/// Deterministic per-case slot population: prompt lengths, cached
/// prefixes, and which slots start prompt-complete all derive from the
/// case's seed coordinate.
fn synth_views(seed: u64, n_slots: usize) -> Vec<SlotView> {
    let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1;
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    (0..n_slots)
        .map(|slot| {
            let prompt_len = (next() % 400 + 1) as usize;
            let cached = if next() % 3 == 0 { (next() as usize) % (prompt_len + 1) } else { 0 };
            // A third of the slots begin prompt-complete (pure decoders).
            let prefilled = if next() % 3 == 0 { prompt_len } else { 0 };
            SlotView { slot, prompt_len, prefilled, cached_tokens: cached, done: false }
        })
        .collect()
}

#[test]
fn chunk_spans_tile_prompts_exactly() {
    check(
        "chunk-spans-tile",
        &[Domain::new(1, 96), Domain::new(1, 6), Domain::new(0, u64::MAX / 2)],
        |c| {
            let (chunk, n_slots, seed) = (c[0] as usize, c[1] as usize, c[2]);
            let composer =
                StepComposer::new(ScheduleConfig::bounded(chunk, TokenBudget::unbounded()));
            let mut views = synth_views(seed, n_slots);
            let mut spans: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n_slots];
            let mut out = MixedStepPlan::default();
            // Hard bound: every step must ingest >= 1 token of some
            // incomplete prompt, so total steps <= total prompt tokens.
            let max_steps: usize = views.iter().map(|v| v.prompt_len).sum::<usize>() + 1;
            for _ in 0..max_steps {
                if views.iter().all(|v| v.prefilled >= v.prompt_len) {
                    break;
                }
                composer.compose_into(views.iter().copied(), BUCKETS, &mut out);
                if out.chunks.is_empty() {
                    return Err("incomplete prompts but no chunk composed".into());
                }
                for span in &out.chunks {
                    let v = &mut views[span.slot];
                    let expect_start = if v.prefilled == 0 {
                        v.cached_tokens.min(v.prompt_len - 1)
                    } else {
                        v.prefilled
                    };
                    if span.start != expect_start {
                        return Err(format!(
                            "slot {} span starts at {} (cursor {})",
                            span.slot, span.start, expect_start
                        ));
                    }
                    if span.len == 0 || span.len > chunk {
                        return Err(format!("span len {} outside 1..={chunk}", span.len));
                    }
                    if span.end() > v.prompt_len {
                        return Err(format!(
                            "span ends at {} past prompt {}",
                            span.end(),
                            v.prompt_len
                        ));
                    }
                    spans[span.slot].push((span.start, span.len));
                    v.prefilled = span.end();
                }
                // Prompt-complete slots leave the sweep (they would become
                // decode rows in the engine; tiling only concerns chunks).
                for v in &mut views {
                    if v.prefilled >= v.prompt_len {
                        v.done = true;
                    }
                }
            }
            for (slot, v) in views.iter().enumerate() {
                if v.prefilled < v.prompt_len {
                    return Err(format!("slot {slot} never finished its prompt"));
                }
                if spans[slot].is_empty() {
                    continue; // started prompt-complete
                }
                // Contiguity + exact tail.
                let mut cursor = spans[slot][0].0;
                for &(start, len) in &spans[slot] {
                    if start != cursor {
                        return Err(format!("slot {slot} gap: {cursor} -> {start}"));
                    }
                    cursor = start + len;
                }
                if cursor != v.prompt_len {
                    return Err(format!(
                        "slot {slot} tiled to {cursor}, prompt is {}",
                        v.prompt_len
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn token_budget_bounds_every_step_decode_first() {
    check(
        "token-budget-bound",
        &[
            Domain::new(1, 64),
            Domain::new(0, 64),
            Domain::new(1, 6),
            Domain::new(0, u64::MAX / 2),
        ],
        |c| {
            let (chunk, extra, n_slots, seed) =
                (c[0] as usize, c[1] as usize, c[2] as usize, c[3]);
            // The validation floor: the budget must cover one decode token
            // per slot and at least one full chunk.
            let limit = chunk.max(n_slots) + extra;
            let cfg = ScheduleConfig::bounded(chunk, TokenBudget::capped(limit));
            cfg.validate(n_slots).map_err(|e| e.to_string())?;
            let composer = StepComposer::new(cfg);
            let mut views = synth_views(seed, n_slots);
            let mut out = MixedStepPlan::default();
            for _ in 0..views.iter().map(|v| v.prompt_len).sum::<usize>() + 1 {
                let runnable = views.iter().any(|v| !v.done);
                composer.compose_into(views.iter().copied(), BUCKETS, &mut out);
                if !runnable {
                    break;
                }
                if out.is_empty() {
                    return Err("runnable slots but empty step (no progress)".into());
                }
                if out.step_tokens() > limit {
                    return Err(format!("step {} tokens > budget {limit}", out.step_tokens()));
                }
                // Decode first: every prompt-complete live slot rides.
                for v in views.iter().filter(|v| !v.done && v.prefilled >= v.prompt_len) {
                    if !out.decode_slots.contains(&v.slot) {
                        return Err(format!("decode slot {} starved by chunks", v.slot));
                    }
                }
                for span in &out.chunks {
                    views[span.slot].prefilled = span.end();
                }
                // Retire: decoders finish after one ride, fresh
                // prompt-completions become decoders next step.
                for v in &mut views {
                    if out.decode_slots.contains(&v.slot) {
                        v.done = true;
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn chunked_engine_matches_monolithic_token_streams() {
    let cfg = Config { cases: 10, ..Default::default() };
    check_with(
        cfg,
        "chunked-equals-monolithic",
        &[Domain::new(1, 128), Domain::new(1, 4), Domain::new(0, u64::MAX / 2)],
        |c| {
            let (chunk, n_req, seed) = (c[0] as usize, c[1] as usize, c[2]);
            let mut state = seed | 1;
            let mut next = move || {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                state
            };
            let reqs: Vec<(usize, usize)> = (0..n_req)
                .map(|_| ((next() % 300 + 1) as usize, (next() % 20 + 1) as usize))
                .collect();
            let run = |schedule: ScheduleConfig| {
                let mut engine = engine_with(schedule, 4);
                for (id, &(p, n)) in reqs.iter().enumerate() {
                    drop(engine.submit(Request::new(id as u64, vec![1; p], n)).unwrap());
                }
                let mut done = engine.run_until_idle().unwrap();
                done.sort_by_key(|f| f.id);
                done
            };
            let mono = run(ScheduleConfig::default());
            let chunked =
                run(ScheduleConfig::bounded(chunk, TokenBudget::unbounded()));
            if mono.len() != chunked.len() {
                return Err(format!("{} vs {} finished", mono.len(), chunked.len()));
            }
            for (a, b) in mono.iter().zip(&chunked) {
                if a.tokens != b.tokens {
                    return Err(format!("request {} token streams diverge", a.id));
                }
                if a.reason != b.reason {
                    return Err(format!(
                        "request {} finish reasons diverge: {:?} vs {:?}",
                        a.id, a.reason, b.reason
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn admission_stays_fifo_within_class_under_chunking() {
    // max_batch 2 forces most requests through the waiting queue, so
    // per-step admission ordering is actually observable.
    let mut engine =
        engine_with(ScheduleConfig::bounded(16, TokenBudget::unbounded()), 2);
    let classes = [Priority::Interactive, Priority::Standard, Priority::Batch];
    for id in 0..9u64 {
        let prompt = vec![1; 24 + (id as usize % 3) * 8];
        let opts = SubmitOptions::default().priority(classes[id as usize % 3]);
        drop(engine.submit_with(Request::new(id, prompt, 6), opts).unwrap());
    }
    let done = engine.run_until_idle().unwrap();
    assert_eq!(done.len(), 9);
    for class in Priority::all() {
        let mut in_class: Vec<_> = done.iter().filter(|f| f.priority == class).collect();
        in_class.sort_by_key(|f| f.id);
        assert_eq!(in_class.len(), 3, "{} requests missing", class.name());
        for pair in in_class.windows(2) {
            assert!(
                pair[0].timing.scheduled_us <= pair[1].timing.scheduled_us,
                "{} class leapfrogged: id {} scheduled after id {}",
                class.name(),
                pair[0].id,
                pair[1].id
            );
        }
    }
}

#[test]
fn kv_accounting_holds_on_every_mid_chunk_step() {
    let cfg = Config { cases: 8, ..Default::default() };
    check_with(
        cfg,
        "kv-invariants-mid-chunk",
        &[Domain::new(1, 96), Domain::new(0, u64::MAX / 2)],
        |c| {
            let (chunk, seed) = (c[0] as usize, c[1]);
            let mut engine =
                engine_with(ScheduleConfig::bounded(chunk, TokenBudget::unbounded()), 4);
            let baseline = engine.block_manager().free_blocks();
            let mut state = seed | 1;
            let mut next = move || {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                state
            };
            for id in 0..3u64 {
                let p = (next() % 300 + 100) as usize;
                drop(engine.submit(Request::new(id, vec![1; p], 8)).unwrap());
            }
            let mut guard = 0;
            while !engine.is_idle() {
                engine.step().map_err(|e| e.to_string())?;
                engine.block_manager().check_invariants().map_err(|e| {
                    format!("block invariants broke mid-chunk (chunk={chunk}): {e}")
                })?;
                guard += 1;
                if guard > 5_000 {
                    return Err("engine failed to drain".into());
                }
            }
            if engine.block_manager().free_blocks() != baseline {
                return Err(format!(
                    "leak: {} free blocks vs baseline {baseline}",
                    engine.block_manager().free_blocks()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn cancel_mid_prefill_frees_every_block() {
    for chunk in [1usize, 17, 32, 96] {
        let mut engine =
            engine_with(ScheduleConfig::bounded(chunk, TokenBudget::unbounded()), 4);
        let baseline = engine.block_manager().free_blocks();
        drop(engine.submit(Request::new(1, vec![1; 500], 32)).unwrap());
        // A few steps in, the prompt is only partially ingested (for
        // small chunks) — the cancel must still release every block the
        // partial prefill charged.
        for _ in 0..4 {
            engine.step().unwrap();
        }
        assert!(engine.cancel(1), "request should be live");
        let done = engine.run_until_idle().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(
            engine.block_manager().free_blocks(),
            baseline,
            "chunk={chunk}: blocks leaked by mid-prefill cancel"
        );
        assert_eq!(engine.block_manager().num_seqs(), 0);
    }
}
