//! Figure 3's u_curve_sweep experiment: kernel-level split sweep s = 1..64
//! with precomputed scheduler metadata, on the simulated H100 — and, with
//! `--real`, the same sweep executed for real through the PJRT CPU backend
//! (absolute times differ from H100; the sim column carries the paper
//! comparison, the real column proves the artifacts run at every s).
//!
//! Run: `cargo run --release --example ucurve_sweep -- [--real]`

use fa3_split::bench_harness::{ucurve, Bencher};
use fa3_split::runtime::{HostTensor, Registry};
use fa3_split::sim::Simulator;
use fa3_split::util::cli;
use fa3_split::util::prng::Rng;
use fa3_split::util::table::{us, Align, Table};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = cli::Parser::new("Figure 3: extended split sweep")
        .flag("real", "also execute each split's artifact through PJRT (CPU)")
        .opt("replays", "301", "interleaved replays per point")
        .parse();

    let sim = Simulator::h100();
    let points = ucurve::run(&sim, args.usize("replays"), 0xF163);

    println!("Figure 3 — split sweep, Batch=1 L_K=512 H_KV=1 D=128 (simulated H100):\n");
    print!("{}", ucurve::render_table(&points));
    println!();
    println!("{}", ucurve::render_plot(&points, 14));
    ucurve::verify(&points).map_err(|e| anyhow::anyhow!(e))?;

    if args.has("real") {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
        let reg = Registry::open(&dir)?;
        let mut rng = Rng::new(2);
        let n = |shape: &[usize], rng: &mut Rng| {
            let count: usize = shape.iter().product();
            HostTensor::f32(shape, (0..count).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        let q = n(&[1, 8, 128], &mut rng);
        let k = n(&[1, 512, 1, 128], &mut rng);
        let v = n(&[1, 512, 1, 128], &mut rng);
        let lens = HostTensor::s32(&[1], vec![512])?;
        let bench = Bencher { warmup_iters: 10, samples: 25, batch_iters: 5 };

        println!("\nReal PJRT CPU execution of the same sweep (runtime structure check):\n");
        let mut t = Table::new(&["num_splits", "CPU latency (µs)"]).align(&[Align::Right; 2]);
        for &s in &ucurve::SWEEP_SPLITS {
            let Some(entry) = reg.manifest.find_kernel(1, 512, 1, s) else {
                continue;
            };
            let exe = reg.executor_for(entry)?;
            let r = bench.bench(&format!("s={s}"), || {
                exe.execute(&[q.clone(), k.clone(), v.clone(), lens.clone()]).unwrap()
            });
            t.row(&[s.to_string(), us(r.mean_ns() / 1e3)]);
        }
        t.print();
        println!("(CPU has no SM-occupancy cliff; this column validates execution, not H100 latency)");
    }
    Ok(())
}
