//! §5.3 reproduction: the 160-configuration regression test matrix.
//!
//! Run: `cargo run --release --example regression_matrix -- [--full]`
//! (`--full` prints every cell, not just the non-1.00x ones.)

use fa3_split::bench_harness::regression;
use fa3_split::sim::Simulator;
use fa3_split::util::cli;
use fa3_split::util::table::{speedup, us, Align, Table};

fn main() {
    let args = cli::Parser::new("§5.3 regression matrix (160 configs)")
        .flag("full", "print all 160 rows")
        .opt("replays", "201", "interleaved replays per cell")
        .parse();

    let sim = Simulator::h100();
    let cells = regression::run(&sim, args.usize("replays"), 0x5E53);

    if args.has("full") {
        let mut t = Table::new(&["Batch", "L_K", "H_KV", "Std (µs)", "Patched (µs)", "Speedup"])
            .align(&[Align::Right; 6]);
        for c in &cells {
            t.row(&[
                c.shape.batch.to_string(),
                c.shape.l_k.to_string(),
                c.shape.h_kv.to_string(),
                us(c.standard_us),
                us(c.patched_us),
                speedup(c.speedup()),
            ]);
        }
        t.print();
        println!();
    }
    print!("{}", regression::render(&cells));
    match regression::verify(&cells) {
        Ok(()) => println!("VERIFIED: no regressions (>= 0.99x); wins only in the paper's target cells"),
        Err(e) => {
            eprintln!("FAILED: {e}");
            std::process::exit(1);
        }
    }
}
