//! Quickstart: the paper's result in sixty seconds.
//!
//! 1. Shows the occupancy collapse (§2.1) and both heuristics' decisions
//!    on the boundary shape.
//! 2. Reproduces the headline A/B cell on the simulated H100.
//! 3. Serves one streaming request through the engine's RequestHandle API
//!    on the simulated backend (the serving surface everything else
//!    builds on).
//! 4. If `make artifacts` has been run, executes the real split-KV kernel
//!    through PJRT and checks split invariance on live numerics.
//!
//! Run: `cargo run --release --example quickstart`

use fa3_split::backend::{AttnGeometry, SimBackend};
use fa3_split::coordinator::{Engine, Request, StreamEvent};
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::planner::PolicyRegistry;
use fa3_split::runtime::{HostTensor, Registry};
use fa3_split::sim::Simulator;
use fa3_split::util::prng::Rng;
use fa3_split::util::table::{speedup, us, Align, Table};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    // --- 1. The decision the paper changes -------------------------------
    // One planner per policy, configured once (H100 defaults); every plan
    // below comes from the same façade the serving engine uses.
    let registry = PolicyRegistry::builtin();
    let mut std_planner = registry.planner("standard").map_err(|e| anyhow::anyhow!(e))?;
    let mut pat_planner = registry.planner("sequence-aware").map_err(|e| anyhow::anyhow!(e))?;

    let shape = DecodeShape::llama70b_tp8(1, 512); // Llama-70B/TP-8 decode
    let plan_std = std_planner.plan(&shape);
    let plan_pat = pat_planner.plan(&shape);

    println!("Shape: Batch=1, L_K=512, H_Q=8, H_KV=1, D=128 (Llama-3.1-70B under TP-8)");
    println!("  nblk = {} KV blocks, work tiles = {}", shape.nblk(), shape.total_mblocks(true));
    println!(
        "  standard heuristic:      s = {} -> {} CTA(s), {:.1}% of {} SMs occupied",
        plan_std.num_splits(),
        plan_std.grid_ctas,
        plan_std.occupancy * 100.0,
        std_planner.device().num_sms
    );
    println!(
        "  sequence-aware (paper):  s = {} -> {} CTAs, {:.1}% occupied",
        plan_pat.num_splits(),
        plan_pat.grid_ctas,
        plan_pat.occupancy * 100.0
    );

    // --- 2. The headline cells on the simulated H100 ---------------------
    let sim = Simulator::h100();
    let mut t = Table::new(&["L_K", "H_KV", "Standard (µs)", "Patched (µs)", "Speedup"])
        .align(&[Align::Right; 5]);
    for (l_k, h_kv) in [(384, 1), (512, 1), (512, 2), (512, 8), (2048, 1)] {
        let s = DecodeShape::decode(1, l_k, 8 * h_kv, h_kv, 128);
        let a = sim.kernel_us(&std_planner.plan(&s).metadata);
        let b = sim.kernel_us(&pat_planner.plan(&s).metadata);
        t.row(&[
            l_k.to_string(),
            h_kv.to_string(),
            us(a),
            us(b),
            speedup(a / b),
        ]);
    }
    println!("\nSimulated H100 kernel latency (paper Table 1 shapes):");
    t.print();

    // --- 3. Streaming serving through the engine --------------------------
    // The serving surface: build an engine over any ExecutionBackend,
    // submit, and consume the RequestHandle's token stream. The handle
    // also carries cancel() and deadlines (see examples/serve_decode.rs).
    let mut engine = Engine::builder(Box::new(SimBackend::h100()))
        .planner(registry.planner("sequence-aware").map_err(|e| anyhow::anyhow!(e))?)
        .geometry(AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 })
        .available_splits(vec![1, 3])
        .build()?;
    let handle = engine
        .submit(Request::new(1, vec![7; 400], 16))
        .map_err(|e| anyhow::anyhow!("refused: {e}"))?;
    engine.run_until_idle()?;
    let streamed: Vec<i32> = std::iter::from_fn(|| handle.try_event())
        .filter_map(|ev| match ev {
            StreamEvent::Token { token, .. } => Some(token),
            _ => None,
        })
        .collect();
    println!(
        "\nServed one request on the simulated backend: streamed {} tokens, \
         attention-TPOT {:.2} µs",
        streamed.len(),
        engine.metrics.tpot().map(|s| s.mean).unwrap_or(0.0)
    );

    // --- 4. Real execution through PJRT (if artifacts exist) -------------
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let reg = Registry::open(&dir)?;
        let mut rng = Rng::new(1);
        let n = |shape: &[usize], rng: &mut Rng| {
            let count: usize = shape.iter().product();
            HostTensor::f32(shape, (0..count).map(|_| rng.normal() as f32).collect()).unwrap()
        };
        let q = n(&[1, 8, 128], &mut rng);
        let k = n(&[1, 512, 1, 128], &mut rng);
        let v = n(&[1, 512, 1, 128], &mut rng);
        let lens = HostTensor::s32(&[1], vec![512])?;
        let mut outs = Vec::new();
        for s in [1usize, 3] {
            let entry = reg.manifest.find_kernel(1, 512, 1, s).expect("kernel artifact");
            let exe = reg.executor_for(entry)?;
            let out = exe.execute(&[q.clone(), k.clone(), v.clone(), lens.clone()])?;
            outs.push(out[0].as_f32()?.to_vec());
        }
        let max_diff = outs[0]
            .iter()
            .zip(&outs[1])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("\nReal PJRT execution of the Pallas-lowered kernel (CPU backend):");
        println!("  s=1 vs s=3 outputs agree to {max_diff:.2e} — splitting is pure scheduling.");
    } else {
        println!("\n(run `make artifacts` to also execute the real kernel through PJRT)");
    }

    println!("\nNext: cargo bench --bench table1_ab | fig3_ucurve | regression_sweep");
    println!("      cargo run --release --example serve_decode | evolve_search");
    Ok(())
}
