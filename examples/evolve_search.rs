//! §3 reproduction: the evolutionary discovery of the split-heuristic flaw
//! (OpenEvolve analog).
//!
//! Runs the generational search over (num_splits, pack_gqa, sm_margin)
//! rule genomes against the simulated H100, prints per-generation
//! progress, renders the best genome as the Python-bindings heuristic
//! (the paper's Figure 1 artifact), and compares it with the conservative
//! distilled C++ policy (§4).
//!
//! Run: `cargo run --release --example evolve_search -- [--generations 30]`

use fa3_split::evolve::{Genome, Search, SearchConfig};
use fa3_split::heuristics::tiles::DecodeShape;
use fa3_split::planner::{Planner, PlannerBuilder};
use fa3_split::sim::Simulator;
use fa3_split::util::cli;

fn main() {
    let args = cli::Parser::new("Evolutionary search over split heuristics (§3)")
        .opt("generations", "30", "EA generations")
        .opt("population", "48", "population size")
        .opt("seed", "58113", "search seed")
        .parse();

    let cfg = SearchConfig {
        seed: args.u64("seed"),
        population: args.usize("population"),
        generations: args.usize("generations"),
        ..Default::default()
    };
    let search = Search::new(cfg, Simulator::h100());

    println!("== Evolutionary search (OpenEvolve analog): minimizing chat-panel TPOT ==\n");
    let report = search.run(|g| {
        println!(
            "gen {:>3}: best TPOT {:.3} µs | mean(valid) {:.3} µs | rejected {}",
            g.generation, g.best_tpot_us, g.mean_valid_tpot_us, g.rejected
        );
    });

    println!("\nupstream heuristic TPOT : {:.3} µs", report.upstream_tpot_us);
    println!("best evolved TPOT       : {:.3} µs", report.best_tpot_us);
    println!("search speedup          : {:.3}x", report.speedup());

    println!("\nBest evolved heuristic rendered as the Python-bindings logic (cf. paper Figure 1):\n");
    println!("{}", report.best.render_python());

    // The §3.3 dissection: what does the winner do at the boundary shape?
    // The genome runs through the same planner façade the engine deploys.
    let boundary = DecodeShape::llama70b_tp8(1, 512);
    let mut best_planner = PlannerBuilder::genome(report.best.clone()).build();
    let md = best_planner.plan(&boundary).metadata;
    println!(
        "at the boundary shape (B=1, L_K=512, H_KV=1): evolved s = {}, pack_gqa = {}, sm_margin = {}",
        md.num_splits, md.pack_gqa, md.sm_margin
    );

    // Compare: paper's Figure-1 candidate and the distilled C++ policy.
    let sim = Simulator::h100();
    let eval = search.evaluator();
    let fig1_tpot = eval.panel_tpot_us(&Genome::figure1());
    println!("\npaper's Figure-1 candidate TPOT : {:.3} µs", fig1_tpot);
    let mut distilled = Planner::sequence_aware();
    let mut total = 0.0;
    let mut steps = 0usize;
    for &(prompt, n) in &fa3_split::workload::ChatWorkload::evolution_panel() {
        for step in 0..n {
            let shape = DecodeShape::llama70b_tp8(1, prompt + step + 1);
            total += sim.kernel_us(&distilled.plan(&shape).metadata);
            steps += 1;
        }
    }
    println!(
        "distilled C++ policy (§4) TPOT  : {:.3} µs  (conservative: trades TPOT for a one-line, regression-free rule)",
        total / steps as f64
    );
    println!(
        "\nThe search rediscovers the paper's mechanism: force num_splits > 1 for short\n\
         single-batch prompts where the static L_K <= 512 guard strands the SMs."
    );
}
