//! End-to-end serving driver (the repo's E2E validation run).
//!
//! Builds the continuous-batching engine over an [`ExecutionBackend`] and
//! serves a synthetic chat workload through the streaming request
//! lifecycle: every `submit` returns a `RequestHandle` whose tokens arrive
//! as they decode, with per-request cancellation and deadlines.
//!
//! With `make artifacts` built, part 1 runs the real PJRT backend (true
//! logits, wall-clock timing); otherwise it is skipped and the example
//! still completes on the simulated backend (what the CI smoke job runs).
//! Part 2 projects the paper's serving-level effect by replaying the same
//! boundary-bucket workload on the simulated H100 under BOTH policies,
//! and demonstrates cancellation + deadlines on the virtual clock.
//!
//! Run: `cargo run --release --example serve_decode -- [--requests 8]
//!       [--tokens 48] [--policy sequence-aware|standard]`

use std::path::PathBuf;
use std::sync::Arc;

use fa3_split::backend::{AttnGeometry, PjrtBackend, SimBackend};
use fa3_split::coordinator::{Engine, EngineConfig, Request, StreamEvent, SubmitOptions};
use fa3_split::planner::PolicyRegistry;
use fa3_split::runtime::Registry;
use fa3_split::util::cli;
use fa3_split::workload::ChatWorkload;

fn main() -> anyhow::Result<()> {
    let policies = PolicyRegistry::builtin();
    let args = cli::Parser::new("End-to-end serving over the execution-backend API")
        .opt("requests", "8", "number of chat requests")
        .opt("tokens", "48", "max new tokens per request")
        .opt("prompt-median", "200", "median prompt length")
        .opt("policy", "sequence-aware", format!("split policy: {}", policies.help_line()))
        .opt("seed", "7", "workload seed")
        .parse();

    let workload = ChatWorkload {
        seed: args.u64("seed"),
        n_requests: args.usize("requests"),
        prompt_median: args.usize("prompt-median"),
        output_mean: args.usize("tokens"),
        output_cap: args.usize("tokens"),
        ..Default::default()
    };
    let requests: Vec<Request> = workload
        .generate()
        .into_iter()
        .map(|g| {
            let mut r = g.request;
            r.max_new_tokens = args.usize("tokens"); // fixed length for comparability
            r
        })
        .collect();

    // ---------------- Real PJRT serving (if artifacts exist) -------------
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut geometry = AttnGeometry { h_q: 8, h_kv: 1, d: 128, max_seq: 1024 };
    if dir.join("manifest.json").exists() {
        println!("== Real serving over PJRT (CPU backend) ==");
        let registry = Arc::new(Registry::open(&dir)?);
        let model = registry.manifest.model.as_ref().unwrap();
        println!(
            "model: preset '{}', {} layers, H_Q={} H_KV={} D={} ({:.1}M params)",
            model.preset,
            model.config.n_layers,
            model.config.n_heads_q,
            model.config.n_heads_kv,
            model.config.head_dim,
            model.config.n_params as f64 / 1e6
        );
        let cfg = EngineConfig::default();
        let backend = PjrtBackend::new(registry.clone(), cfg.batcher.max_batch)?;
        let mut engine = Engine::builder(Box::new(backend))
            .planner(policies.planner(&args.str("policy")).map_err(|e| anyhow::anyhow!(e))?)
            .config(cfg)
            .build()?;
        geometry = AttnGeometry {
            h_q: model.config.n_heads_q,
            h_kv: model.config.n_heads_kv,
            d: model.config.head_dim,
            max_seq: model.config.max_seq,
        };
        println!(
            "engine: policy '{}', serving {} requests x {} tokens\n",
            engine.policy_name(),
            requests.len(),
            args.usize("tokens")
        );
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for r in requests.clone() {
            handles.push(engine.submit(r).map_err(|e| anyhow::anyhow!("refused: {e}"))?);
        }
        let finished = engine.run_until_idle()?;
        let wall = t0.elapsed();
        engine.metrics.wall_us = wall.as_micros() as u64;

        println!("served {} requests in {:.2}s", finished.len(), wall.as_secs_f64());
        print!("{}", engine.metrics.report());
        // Consume one stream to show the handle-side view.
        let sample = handles.remove(0);
        let id = sample.id();
        let streamed: Vec<i32> = std::iter::from_fn(|| sample.try_event())
            .filter_map(|ev| match ev {
                StreamEvent::Token { token, .. } => Some(token),
                _ => None,
            })
            .collect();
        println!(
            "sample stream (req {id}): {:?}... ({} tokens)\n",
            &streamed[..streamed.len().min(8)],
            streamed.len()
        );
    } else {
        println!("== PJRT serving skipped (artifacts/ not built — run `make artifacts`) ==\n");
    }

    // ---------------- Simulated H100 projection, both policies -----------
    // The paper's target regime is Batch = 1 (per-device Llama-70B/TP-8
    // chat): run the projection with a single-slot engine and prompts that
    // decode across the L_K = 385..512 boundary bucket.
    println!("== Simulated-H100 serving projection (Batch=1 chat regime, A/B) ==");
    let boundary_workload = ChatWorkload {
        seed: args.u64("seed"),
        n_requests: args.usize("requests"),
        prompt_median: 400,
        output_mean: 96,
        output_cap: 96,
        ..Default::default()
    };
    let mut results = Vec::new();
    for policy_name in ["standard", "sequence-aware"] {
        let mut sim_engine = Engine::builder(Box::new(SimBackend::h100()))
            .planner(policies.planner(policy_name).map_err(|e| anyhow::anyhow!(e))?)
            .geometry(geometry)
            .available_splits(vec![1, 3])
            .config(EngineConfig {
                batcher: fa3_split::coordinator::BatcherConfig {
                    max_batch: 1,
                    batch_buckets: vec![1],
                },
                ..Default::default()
            })
            .build()?;
        for g in boundary_workload.generate() {
            let mut r = g.request;
            r.max_new_tokens = 96;
            sim_engine.submit(r).map_err(|e| anyhow::anyhow!("refused: {e}"))?;
        }
        let done = sim_engine.run_until_idle()?;
        let tpot = sim_engine.metrics.tpot().map(|s| s.mean).unwrap_or(0.0);
        println!(
            "  {policy_name:<14} attention-TPOT {:.2} µs/token ({} requests, splits {:?})",
            tpot,
            done.len(),
            sim_engine
                .metrics
                .split_histogram
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(s, &c)| format!("s{s}:{c}"))
                .collect::<Vec<_>>()
        );
        results.push(tpot);
    }
    if results.len() == 2 && results[1] > 0.0 {
        println!(
            "  projected serving speedup (standard/patched): {:.3}x",
            results[0] / results[1]
        );
    }

    // ---------------- Lifecycle demo: cancellation + deadline ------------
    println!("\n== Request lifecycle (virtual clock) ==");
    let mut engine = Engine::builder(Box::new(SimBackend::h100()))
        .planner(policies.planner("sequence-aware").map_err(|e| anyhow::anyhow!(e))?)
        .geometry(geometry)
        .available_splits(vec![1, 3])
        .build()?;
    let cancelled = engine.submit(Request::new(100, vec![1; 200], 500)).unwrap();
    let deadlined = engine
        .submit_with(
            Request::new(101, vec![1; 200], 500),
            SubmitOptions::default().deadline_us(1_000),
        )
        .unwrap();
    let normal = engine.submit(Request::new(102, vec![1; 200], 32)).unwrap();
    // A few steps in, the client changes its mind.
    for _ in 0..10 {
        engine.step()?;
    }
    cancelled.cancel();
    engine.run_until_idle()?;
    for (name, h) in [("cancelled", cancelled), ("deadlined", deadlined), ("normal", normal)] {
        let fin = h.wait().finished().expect("terminal event");
        println!(
            "  {name:<10} -> {:?} after {} tokens",
            fin.reason,
            fin.tokens.len()
        );
    }
    assert_eq!(engine.block_manager().num_seqs(), 0, "all KV blocks released");
    println!("  all KV blocks released; admission stats: {:?}", engine.admission_stats());
    Ok(())
}
