//! End-to-end serving driver (the repo's E2E validation run).
//!
//! Loads the AOT-compiled synthetic GQA model (the per-device shape of
//! Llama-70B/TP-8), starts the continuous-batching engine on the real PJRT
//! runtime, and serves a synthetic chat workload — batched prefill +
//! decode with the split decision made per step from scheduler metadata.
//! Reports TTFT / TPOT / throughput and the split histogram, then repeats
//! the same workload on the simulated-H100 backend under BOTH policies to
//! project the paper's serving-level effect.
//!
//! Run: `cargo run --release --example serve_decode -- [--requests 8]
//!       [--tokens 48] [--policy patched|standard]`
//! Requires `make artifacts`.

use std::path::PathBuf;
use std::sync::Arc;

use fa3_split::coordinator::scheduler::AttnGeometry;
use fa3_split::coordinator::{Engine, EngineConfig, Request};
use fa3_split::planner::PolicyRegistry;
use fa3_split::runtime::Registry;
use fa3_split::sim::Simulator;
use fa3_split::util::cli;
use fa3_split::workload::ChatWorkload;

fn main() -> anyhow::Result<()> {
    let policies = PolicyRegistry::builtin();
    let args = cli::Parser::new("End-to-end serving over the AOT artifacts")
        .opt("requests", "8", "number of chat requests")
        .opt("tokens", "48", "max new tokens per request")
        .opt("prompt-median", "200", "median prompt length")
        .opt("policy", "sequence-aware", format!("split policy: {}", policies.help_line()))
        .opt("seed", "7", "workload seed")
        .parse();

    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );

    let workload = ChatWorkload {
        seed: args.u64("seed"),
        n_requests: args.usize("requests"),
        prompt_median: args.usize("prompt-median"),
        output_mean: args.usize("tokens"),
        output_cap: args.usize("tokens"),
        ..Default::default()
    };
    let requests: Vec<Request> = workload
        .generate()
        .into_iter()
        .map(|g| {
            let mut r = g.request;
            r.max_new_tokens = args.usize("tokens"); // fixed length for comparability
            r
        })
        .collect();

    // ---------------- Real PJRT serving ----------------------------------
    println!("== Real serving over PJRT (CPU backend) ==");
    let registry = Arc::new(Registry::open(&dir)?);
    let model = registry.manifest.model.as_ref().unwrap();
    println!(
        "model: preset '{}', {} layers, H_Q={} H_KV={} D={} ({:.1}M params)",
        model.preset,
        model.config.n_layers,
        model.config.n_heads_q,
        model.config.n_heads_kv,
        model.config.head_dim,
        model.config.n_params as f64 / 1e6
    );
    let mut engine = Engine::with_pjrt(
        registry.clone(),
        policies.planner(&args.str("policy")).map_err(|e| anyhow::anyhow!(e))?,
        EngineConfig::default(),
    )?;
    println!(
        "engine: policy '{}', serving {} requests x {} tokens\n",
        engine.policy_name(),
        requests.len(),
        args.usize("tokens")
    );
    let t0 = std::time::Instant::now();
    for r in requests.clone() {
        engine.submit(r);
    }
    let finished = engine.run_until_idle()?;
    let wall = t0.elapsed();
    engine.metrics.wall_us = wall.as_micros() as u64;

    println!("served {} requests in {:.2}s", finished.len(), wall.as_secs_f64());
    print!("{}", engine.metrics.report());
    let sample = &finished[0];
    println!(
        "sample generation (req {}): prompt {} tokens -> {:?}...\n",
        sample.id,
        sample.prompt_len,
        &sample.tokens[..sample.tokens.len().min(8)]
    );

    // ---------------- Simulated H100 projection, both policies -----------
    // The paper's target regime is Batch = 1 (per-device Llama-70B/TP-8
    // chat): run the projection with a single-slot engine and prompts that
    // decode across the L_K = 385..512 boundary bucket.
    println!("== Simulated-H100 serving projection (Batch=1 chat regime, A/B) ==");
    let geometry = AttnGeometry {
        h_q: model.config.n_heads_q,
        h_kv: model.config.n_heads_kv,
        d: model.config.head_dim,
        max_seq: model.config.max_seq,
    };
    let boundary_workload = ChatWorkload {
        seed: args.u64("seed"),
        n_requests: args.usize("requests"),
        prompt_median: 400,
        output_mean: 96,
        output_cap: 96,
        ..Default::default()
    };
    let mut results = Vec::new();
    for policy_name in ["standard", "sequence-aware"] {
        let mut sim_engine = Engine::with_simulator(
            Simulator::h100(),
            policies.planner(policy_name).map_err(|e| anyhow::anyhow!(e))?,
            geometry,
            vec![1, 3],
            EngineConfig {
                batcher: fa3_split::coordinator::BatcherConfig {
                    max_batch: 1,
                    batch_buckets: vec![1],
                },
                ..Default::default()
            },
        );
        for g in boundary_workload.generate() {
            let mut r = g.request;
            r.max_new_tokens = 96;
            sim_engine.submit(r);
        }
        let done = sim_engine.run_until_idle()?;
        let tpot = sim_engine.metrics.tpot().map(|s| s.mean).unwrap_or(0.0);
        println!(
            "  {policy_name:<9} attention-TPOT {:.2} µs/token ({} requests, splits {:?})",
            tpot,
            done.len(),
            sim_engine
                .metrics
                .split_histogram
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(s, &c)| format!("s{s}:{c}"))
                .collect::<Vec<_>>()
        );
        results.push(tpot);
    }
    if results.len() == 2 && results[1] > 0.0 {
        println!(
            "  projected serving speedup (standard/patched): {:.3}x",
            results[0] / results[1]
        );
    }
    Ok(())
}
