"""AOT builder: manifest structure, weights ABI, HLO text validity.

These tests run the builder in --fast mode into a temp dir and validate the
contract the rust runtime (rust/src/runtime/artifacts.rs) depends on.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

PY_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--fast", "--preset", "small"],
        cwd=PY_DIR, check=True, capture_output=True,
    )
    with open(out / "manifest.json") as f:
        manifest = json.load(f)
    return out, manifest


def test_manifest_schema(built):
    out, m = built
    assert m["version"] == 2
    assert m["entries"], "no artifacts built"
    for e in m["entries"]:
        assert e["kind"] in ("kernel", "decode", "prefill")
        assert (out / e["hlo"]).exists()
        for sig in e["inputs"] + e["outputs"]:
            assert sig["dtype"] in ("f32", "s32", "bf16")
            assert all(isinstance(d, int) and d >= 1 for d in sig["shape"])


def test_hlo_text_is_parseable_text(built):
    out, m = built
    for e in m["entries"][:4]:
        text = (out / e["hlo"]).read_text()
        assert text.startswith("HloModule"), e["name"]
        assert "ENTRY" in text


def test_kernel_entry_signatures(built):
    _, m = built
    kernels = [e for e in m["entries"] if e["kind"] == "kernel"]
    assert kernels
    for e in kernels:
        meta = e["meta"]
        b, lk = meta["batch"], meta["l_k"]
        hq, hkv, d = meta["h_q"], meta["h_kv"], meta["d"]
        assert [s["shape"] for s in e["inputs"]] == [
            [b, hq, d], [b, lk, hkv, d], [b, lk, hkv, d], [b],
        ]
        assert e["inputs"][3]["dtype"] == "s32"
        assert e["outputs"][0]["shape"] == [b, hq, d]
        assert hq == 8 * hkv  # Llama-70B 8:1 GQA ratio throughout Table 1


def test_ucurve_and_table1_coverage_full_matrix():
    """The non-fast matrix must cover Table 1 pairs and the Fig-3 sweep."""
    from compile.aot import TABLE1_KERNELS, UCURVE_SPLITS

    # Table 1's winning cells and their s=1 baselines must be present.
    assert (512, 1, 1) in TABLE1_KERNELS and (512, 1, 3) in TABLE1_KERNELS
    assert (512, 2, 1) in TABLE1_KERNELS and (512, 2, 3) in TABLE1_KERNELS
    assert (512, 8, 1) in TABLE1_KERNELS  # unchanged control
    # Fig 3 sweep spans s = 1 .. 64.
    assert min(UCURVE_SPLITS) == 1 and max(UCURVE_SPLITS) == 64
    assert 3 in UCURVE_SPLITS  # the paper's chosen split


def test_model_block_weights_abi(built):
    out, m = built
    mb = m["model"]
    assert mb["preset"] == "small"
    size = os.path.getsize(out / mb["weights"])
    # Offsets are contiguous, sizes consistent with shapes (f32 = 4 bytes).
    offset = 0
    for p in mb["params"]:
        assert p["offset_bytes"] == offset
        assert p["size_bytes"] == 4 * int(np.prod(p["shape"]))
        offset += p["size_bytes"]
    assert offset == size
    assert sum(int(np.prod(p["shape"])) for p in mb["params"]) == \
        mb["config"]["n_params"]


def test_decode_entry_input_layout(built):
    out, m = built
    decs = [e for e in m["entries"] if e["kind"] == "decode"]
    assert decs
    n_params = len(m["model"]["params"])
    cfg = m["model"]["config"]
    for e in decs:
        b = e["meta"]["batch"]
        cache = [cfg["n_layers"], b, cfg["max_seq"], cfg["n_heads_kv"],
                 cfg["head_dim"]]
        ins = e["inputs"]
        assert len(ins) == 4 + n_params
        assert ins[0]["shape"] == [b] and ins[0]["dtype"] == "s32"   # tokens
        assert ins[1]["shape"] == [b] and ins[1]["dtype"] == "s32"   # positions
        assert ins[2]["shape"] == cache and ins[3]["shape"] == cache
        outs = e["outputs"]
        assert outs[0]["shape"] == [b, cfg["vocab"]]
        assert outs[1]["shape"] == cache and outs[2]["shape"] == cache


def test_weights_deterministic(built, tmp_path):
    """Same seed ⇒ bit-identical weights.bin (reproducible artifacts)."""
    out, m = built
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path),
         "--fast", "--preset", "small", "--skip-kernels"],
        cwd=PY_DIR, check=True, capture_output=True,
    )
    a = (out / "weights.bin").read_bytes()
    b = (tmp_path / "weights.bin").read_bytes()
    assert a == b
