"""L1 correctness: the split-KV Pallas kernel vs the pure-jnp oracle.

The paper's core safety claim is that ``num_splits`` is a *scheduling*
parameter: any split count must reproduce the unsplit math bit-for-bit up
to float tolerance. These tests sweep shapes, dtypes, and split counts
(including the over-split s > nblk regime Figure 3 exercises up to s=64)
with hypothesis, plus deterministic edge cases.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_decode import KV_BLOCK, flash_decode, split_geometry
from compile.kernels.ref import attention_decode_ref


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _check(b, h_q, h_kv, d, l_k, s, dtype, seed=0, pack_gqa=True,
           kv_lens=None, scale=None, atol=None):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h_q, d), dtype)
    k = _rand(rng, (b, l_k, h_kv, d), dtype)
    v = _rand(rng, (b, l_k, h_kv, d), dtype)
    lens = None if kv_lens is None else jnp.asarray(kv_lens, jnp.int32)
    out = flash_decode(q, k, v, lens, num_splits=s, pack_gqa=pack_gqa,
                       softmax_scale=scale)
    ref = attention_decode_ref(q, k, v, lens, softmax_scale=scale)
    assert out.shape == ref.shape == (b, h_q, d)
    assert out.dtype == q.dtype
    if atol is None:
        atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 3),
    h_kv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 8]),
    d=st.sampled_from([32, 64, 128]),
    l_k=st.integers(1, 700),
    s=st.integers(1, 12),
    seed=st.integers(0, 2**31),
)
def test_matches_oracle_f32(b, h_kv, group, d, l_k, s, seed):
    _check(b, group * h_kv, h_kv, d, l_k, s, jnp.float32, seed=seed)


@settings(max_examples=20, deadline=None)
@given(
    l_k=st.integers(1, 600),
    s=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_matches_oracle_bf16(l_k, s, seed):
    _check(1, 8, 1, 128, l_k, s, jnp.bfloat16, seed=seed)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    l_k=st.integers(2, 500),
    s=st.integers(1, 8),
    seed=st.integers(0, 2**31),
    data=st.data(),
)
def test_variable_kv_lens(b, l_k, s, seed, data):
    lens = data.draw(
        st.lists(st.integers(1, l_k), min_size=b, max_size=b), label="lens"
    )
    _check(b, 8, 1, 64, l_k, s, jnp.float32, seed=seed, kv_lens=lens)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(1, 64), seed=st.integers(0, 2**31))
def test_split_count_invariance_paper_shape(s, seed):
    """Figure 3's sweep domain: B=1, L_K=512, H_KV=1, D=128, s in 1..64.

    Every split count must produce the same attention output — splitting is
    scheduling, never math.
    """
    _check(1, 8, 1, 128, 512, s, jnp.float32, seed=seed)


# ---------------------------------------------------------------------------
# Deterministic edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2, 3, 4, 5, 8, 16])
def test_table1_boundary_bucket(s):
    """nblk = 4 boundary bucket (L_K = 512), H_KV in {1, 2}: the paper's
    target shapes."""
    _check(1, 8, 1, 128, 512, s, jnp.float32)
    _check(1, 16, 2, 128, 512, s, jnp.float32)


def test_single_token_cache():
    _check(1, 8, 1, 64, 1, 1, jnp.float32)
    _check(1, 8, 1, 64, 1, 4, jnp.float32)  # heavy over-split of 1 token


def test_kv_len_one_of_many():
    _check(2, 8, 2, 64, 300, 3, jnp.float32, kv_lens=[1, 300])


def test_oversplit_beyond_nblk():
    # nblk = ceil(130/128) = 2, s = 16: 14 splits see only padding.
    _check(1, 4, 1, 32, 130, 16, jnp.float32)


def test_pack_gqa_false_matches():
    _check(2, 8, 2, 64, 200, 2, jnp.float32, pack_gqa=False)
    _check(1, 8, 1, 128, 512, 3, jnp.float32, pack_gqa=False)


def test_custom_softmax_scale():
    _check(1, 8, 1, 64, 256, 2, jnp.float32, scale=0.5)
    _check(1, 8, 1, 64, 256, 2, jnp.float32, scale=1.0 / math.sqrt(999))


def test_mqa_vs_gqa_head_layouts():
    for h_kv in (1, 2, 4, 8):
        _check(1, 8, h_kv, 64, 384, 3, jnp.float32)


def test_large_magnitude_scores_stable():
    """Softmax stability: huge logits must not overflow through the split
    combine (the LSE path)."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(100.0 * rng.standard_normal((1, 8, 64)), jnp.float32)
    k = jnp.asarray(100.0 * rng.standard_normal((1, 256, 1, 64)), jnp.float32)
    v = _rand(rng, (1, 256, 1, 64), jnp.float32)
    out = flash_decode(q, k, v, num_splits=4)
    ref = attention_decode_ref(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_identical_keys_uniform_attention():
    """All-equal keys ⇒ output is the mean of values, for any split."""
    b, h, d, l = 1, 4, 32, 256
    q = jnp.ones((b, h, d), jnp.float32)
    k = jnp.ones((b, l, 1, d), jnp.float32)
    rng = np.random.default_rng(3)
    v = _rand(rng, (b, l, 1, d), jnp.float32)
    expect = np.broadcast_to(np.asarray(v.mean(axis=1)), (b, h, d))
    for s in (1, 2, 3, 7):
        out = flash_decode(q, k, v, num_splits=s)
        np.testing.assert_allclose(np.asarray(out), expect, atol=1e-5)


def test_determinism():
    rng = np.random.default_rng(9)
    q = _rand(rng, (1, 8, 64), jnp.float32)
    k = _rand(rng, (1, 512, 1, 64), jnp.float32)
    v = _rand(rng, (1, 512, 1, 64), jnp.float32)
    a = flash_decode(q, k, v, num_splits=3)
    b = flash_decode(q, k, v, num_splits=3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# split_geometry unit tests (the static shape arithmetic the rust heuristics
# must agree with — mirrored in rust/src/heuristics/tiles.rs)
# ---------------------------------------------------------------------------

def test_split_geometry_basics():
    # L_K = 512 -> nblk = 4 (the paper's boundary bucket).
    assert split_geometry(512, 1) == (4, 4, 512, 512)
    assert split_geometry(512, 3) == (4, 2, 256, 768)
    assert split_geometry(512, 4) == (4, 1, 128, 512)
    assert split_geometry(512, 64) == (4, 1, 128, 8192)
    assert split_geometry(384, 1) == (3, 3, 384, 384)
    assert split_geometry(1, 1) == (1, 1, 128, 128)


@settings(max_examples=100, deadline=None)
@given(l_k=st.integers(1, 10_000), s=st.integers(1, 64))
def test_split_geometry_invariants(l_k, s):
    nblk, bps, split_len, padded = split_geometry(l_k, s)
    assert nblk == -(-l_k // KV_BLOCK)
    assert split_len == bps * KV_BLOCK
    assert padded == s * split_len
    assert padded >= l_k                      # all tokens covered
    assert bps == -(-nblk // s)               # ceil division
    assert (s == 1) == (padded == split_len)


def test_split_geometry_rejects_bad_args():
    with pytest.raises(ValueError):
        split_geometry(0, 1)
    with pytest.raises(ValueError):
        split_geometry(128, 0)


def test_shape_validation_errors():
    q = jnp.zeros((1, 8, 64), jnp.float32)
    k = jnp.zeros((1, 128, 3, 64), jnp.float32)  # 8 % 3 != 0
    with pytest.raises(ValueError):
        flash_decode(q, k, k, num_splits=1)
    k2 = jnp.zeros((1, 128, 1, 32), jnp.float32)  # head-dim mismatch
    with pytest.raises(ValueError):
        flash_decode(q, k2, k2, num_splits=1)
    v_bad = jnp.zeros((1, 64, 1, 64), jnp.float32)
    k3 = jnp.zeros((1, 128, 1, 64), jnp.float32)
    with pytest.raises(ValueError):
        flash_decode(q, k3, v_bad, num_splits=1)
