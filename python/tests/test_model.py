"""L2 correctness: the GQA transformer decode model.

Key invariants:
  * decode_step output is independent of ``num_splits`` (the scheduling
    knob must never change the math — the paper's safety property lifted
    to the whole model),
  * prefill(prompt) ≡ decoding the prompt token-by-token,
  * batch elements are independent (continuous-batching prerequisite),
  * parameter ABI (param_specs ordering) is stable and complete.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

TINY = M.ModelConfig(
    n_layers=2, d_model=64, n_heads_q=4, n_heads_kv=1, head_dim=16,
    ffn_dim=128, vocab=97, max_seq=64,
)
TINY_GQA = M.ModelConfig(
    n_layers=2, d_model=64, n_heads_q=4, n_heads_kv=2, head_dim=16,
    ffn_dim=128, vocab=97, max_seq=64,
)


def _fresh_cache(cfg, b):
    shape = (cfg.n_layers, b, cfg.max_seq, cfg.n_heads_kv, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def _decode_n(cfg, params, tokens, positions, kv_k, kv_v, n, num_splits):
    outs = []
    for _ in range(n):
        logits, kv_k, kv_v = M.decode_step(
            cfg, params, tokens, positions, kv_k, kv_v, num_splits=num_splits
        )
        tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        positions = positions + 1
        outs.append(np.asarray(logits))
    return outs, kv_k, kv_v


@pytest.mark.parametrize("cfg", [TINY, TINY_GQA], ids=["mqa", "gqa2"])
@pytest.mark.parametrize("s", [2, 3, 5])
def test_decode_split_invariance(cfg, s):
    params = M.init_params(cfg, seed=1)
    rng = np.random.default_rng(0)
    b = 2
    kv_k, kv_v = _fresh_cache(cfg, b)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b,)), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    ref, _, _ = _decode_n(cfg, params, toks, pos, kv_k, kv_v, 4, 1)
    got, _, _ = _decode_n(cfg, params, toks, pos, kv_k, kv_v, 4, s)
    for a, b_ in zip(ref, got):
        np.testing.assert_allclose(a, b_, atol=1e-4)


def test_prefill_equals_decode_loop():
    cfg, params = TINY, M.init_params(TINY, seed=2)
    rng = np.random.default_rng(1)
    p_len = 10
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, p_len)), jnp.int32)
    kv_k, kv_v = _fresh_cache(cfg, 1)

    lg_p, k_p, v_p = M.prefill(cfg, params, prompt, jnp.asarray([p_len], jnp.int32),
                               kv_k, kv_v)
    k_d, v_d = kv_k, kv_v
    for t in range(p_len):
        lg_d, k_d, v_d = M.decode_step(
            cfg, params, prompt[:, t], jnp.asarray([t], jnp.int32), k_d, v_d
        )
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d), atol=1e-3)
    # Cache contents for the prompt region must agree too.
    np.testing.assert_allclose(
        np.asarray(k_p[:, :, :p_len]), np.asarray(k_d[:, :, :p_len]), atol=1e-3
    )
    np.testing.assert_allclose(
        np.asarray(v_p[:, :, :p_len]), np.asarray(v_d[:, :, :p_len]), atol=1e-3
    )


def test_prefill_respects_padding():
    """Right-padding beyond kv_lens must not influence the last-token logits."""
    cfg, params = TINY, M.init_params(TINY, seed=3)
    rng = np.random.default_rng(2)
    true_len = 6
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, true_len)), jnp.int32)
    padded_a = jnp.pad(prompt, ((0, 0), (0, 6)), constant_values=0)
    padded_b = jnp.pad(prompt, ((0, 0), (0, 6)), constant_values=42)
    kv_k, kv_v = _fresh_cache(cfg, 1)
    lens = jnp.asarray([true_len], jnp.int32)
    lg_a, _, _ = M.prefill(cfg, params, padded_a, lens, kv_k, kv_v)
    lg_b, _, _ = M.prefill(cfg, params, padded_b, lens, kv_k, kv_v)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b), atol=1e-5)


def test_batch_independence():
    """Row b of a batched decode must equal the same sequence decoded alone."""
    cfg, params = TINY, M.init_params(TINY, seed=4)
    rng = np.random.default_rng(3)
    b = 3
    kv_k, kv_v = _fresh_cache(cfg, b)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b,)), jnp.int32)
    pos = jnp.asarray([0, 0, 0], jnp.int32)
    lg_batch, _, _ = M.decode_step(cfg, params, toks, pos, kv_k, kv_v)
    for row in range(b):
        k1, v1 = _fresh_cache(cfg, 1)
        lg_one, _, _ = M.decode_step(
            cfg, params, toks[row:row + 1], pos[row:row + 1], k1, v1
        )
        np.testing.assert_allclose(
            np.asarray(lg_batch[row]), np.asarray(lg_one[0]), atol=1e-4
        )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31), s=st.integers(1, 6))
def test_decode_finite_logits(seed, s):
    cfg, params = TINY, M.init_params(TINY, seed=5)
    rng = np.random.default_rng(seed)
    kv_k, kv_v = _fresh_cache(cfg, 1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1,)), jnp.int32)
    outs, _, _ = _decode_n(cfg, params, toks, jnp.zeros((1,), jnp.int32),
                           kv_k, kv_v, 3, s)
    for o in outs:
        assert np.isfinite(o).all()


def test_param_specs_abi():
    cfg = TINY
    specs = M.param_specs(cfg)
    names = [n for n, _ in specs]
    # Stable ordering: embed first, w_out last, 9 tensors per layer.
    assert names[0] == "embed"
    assert names[-1] == "w_out"
    assert names[-2] == "out_norm"
    assert len(names) == 2 * 9 + 3
    assert len(set(names)) == len(names)
    # n_params matches the spec shapes exactly.
    total = sum(int(np.prod(s)) for _, s in specs)
    assert total == cfg.n_params()


def test_flatten_roundtrip():
    cfg = TINY
    params = M.init_params(cfg, seed=6)
    flat = M.flatten_params(cfg, params)
    back = M.unflatten_params(cfg, flat)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(back[k]))
    with pytest.raises(ValueError):
        M.unflatten_params(cfg, flat[:-1])


def test_config_validation():
    with pytest.raises(ValueError):
        M.ModelConfig(n_heads_q=3, n_heads_kv=2)
    with pytest.raises(ValueError):
        M.ModelConfig(n_heads_q=8, n_heads_kv=1, head_dim=100, d_model=1024)


def test_presets_sane():
    for name, cfg in M.PRESETS.items():
        assert cfg.n_params() > 0
        assert cfg.n_heads_q % cfg.n_heads_kv == 0
    paper = M.PRESETS["paper"]
    # The paper's per-device Llama-70B/TP-8 attention geometry.
    assert (paper.n_heads_q, paper.n_heads_kv, paper.head_dim) == (8, 1, 128)


def test_rope_rotation_property():
    """RoPE must preserve vector norm (it is a rotation)."""
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((2, 4, 16)), jnp.float32)
    pos = jnp.asarray([3, 11], jnp.int32)
    y = M._rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # Position 0 is the identity.
    y0 = M._rope(x, jnp.zeros((2,), jnp.int32), 10000.0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(x), atol=1e-6)
