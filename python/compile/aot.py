"""AOT artifact builder: lower L2/L1 JAX programs to HLO text + manifest.

Run once at build time (``make artifacts``); the rust runtime then loads
``artifacts/manifest.json`` and the referenced ``*.hlo.txt`` files and never
touches Python again.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the rust side's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced:
  * kernel artifacts  — the standalone L1 split-KV decode-attention kernel
    for each (B, L_K, H_Q, H_KV, D, s) variant the benches/examples need:
    the Figure-3 u-curve sweep set and the Table-1 A/B pairs.
  * model artifacts   — decode_step and prefill of the synthetic GQA model
    for each (batch-bucket, num_splits) / (batch-bucket, prompt-bucket)
    variant the serving engine routes to (vLLM-style shape bucketing, the
    CUDA-Graph analog).
  * weights.bin       — flat little-endian f32 dump of the model parameters
    in ``param_specs`` order (the positional ABI the rust runtime follows).
  * manifest.json     — index of everything above with full input/output
    shape+dtype signatures.

Usage: ``cd python && python -m compile.aot --out ../artifacts
[--preset paper|small|gqa2] [--fast]``
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.flash_decode import flash_decode

MANIFEST_VERSION = 2

# (L_K, H_KV, num_splits) kernel variants for Table 1 A/B on the real CPU
# backend. H_Q = 8 * H_KV (Llama-70B's 8:1 GQA ratio), D = 128, Batch = 1.
TABLE1_KERNELS = [
    (128, 1, 1), (128, 1, 3),
    (256, 1, 1), (256, 1, 3),
    (384, 1, 1), (384, 1, 3),
    (512, 1, 1), (512, 1, 3),
    (512, 2, 1), (512, 2, 3),
    (512, 8, 1),
    (2048, 1, 1), (2048, 1, 8),
]

# Figure 3 u-curve sweep: Batch=1, L_K=512, H_KV=1, D=128, s = 1..64.
UCURVE_SPLITS = [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64]

# Serving shape buckets (vLLM-style): batch x num_splits for decode,
# batch x prompt-length for prefill. Prompt buckets are power-of-two-ish so
# a median-200-token chat prompt pays a 256^2 prefill, not 512^2 (§Perf
# opt-1 in EXPERIMENTS.md: finer buckets cut TTFT ~2.8x on the CPU path).
DECODE_BATCH_BUCKETS = [1, 2, 4]
DECODE_SPLITS = [1, 3]
PREFILL_PROMPT_BUCKETS = [64, 128, 256, 512]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(avals):
    out = []
    for a in avals:
        dt = {"float32": "f32", "int32": "s32", "bfloat16": "bf16"}[str(a.dtype)]
        out.append({"shape": [int(d) for d in a.shape], "dtype": dt})
    return out


def _lower_entry(name, kind, fn, example_args, meta, out_dir):
    """jit-lower ``fn`` at ``example_args`` and write <name>.hlo.txt."""
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    in_avals = [jax.core.get_aval(a) for a in jax.tree_util.tree_leaves(example_args)]
    out_avals = jax.tree_util.tree_leaves(lowered.out_info)
    entry = {
        "name": name,
        "kind": kind,
        "hlo": fname,
        "meta": meta,
        "inputs": _sig(in_avals),
        "outputs": _sig(out_avals),
    }
    print(f"  [{kind:7s}] {name}: {len(text) / 1e6:.2f} MB HLO "
          f"({time.time() - t0:.1f}s)")
    return entry


def build_kernel_entries(out_dir, fast=False):
    """Standalone attention-kernel artifacts (Table 1 + Figure 3 shapes)."""
    entries = []
    variants = []
    for lk, hkv, s in TABLE1_KERNELS:
        variants.append((1, lk, 8 * hkv, hkv, 128, s, "table1"))
    for s in UCURVE_SPLITS:
        if (512, 1, s) not in TABLE1_KERNELS:
            variants.append((1, 512, 8, 1, 128, s, "ucurve"))
    if fast:
        variants = [v for v in variants if v[1] <= 512 and v[5] <= 4]

    seen = set()
    for b, lk, hq, hkv, d, s, group in variants:
        name = f"attn_b{b}_lk{lk}_hq{hq}_hkv{hkv}_d{d}_s{s}"
        if name in seen:
            continue
        seen.add(name)

        def fn(q, k, v, kv_lens, _s=s):
            return flash_decode(q, k, v, kv_lens, num_splits=_s)

        args = (
            jax.ShapeDtypeStruct((b, hq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, lk, hkv, d), jnp.float32),
            jax.ShapeDtypeStruct((b, lk, hkv, d), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        )
        meta = {"group": group, "batch": b, "l_k": lk, "h_q": hq,
                "h_kv": hkv, "d": d, "num_splits": s}
        entries.append(_lower_entry(name, "kernel", fn, args, meta, out_dir))
    return entries


def build_model_entries(cfg: M.ModelConfig, preset: str, out_dir, fast=False):
    """decode_step / prefill artifacts + weights.bin for the serving model."""
    params = M.init_params(cfg, seed=0)
    flat = M.flatten_params(cfg, params)
    specs = M.param_specs(cfg)

    # weights.bin: positional f32 dump.
    offset = 0
    param_index = []
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for (name, shape), arr in zip(specs, flat):
            data = np.asarray(arr, dtype="<f4").tobytes()
            f.write(data)
            param_index.append({
                "name": name,
                "shape": list(shape),
                "offset_bytes": offset,
                "size_bytes": len(data),
            })
            offset += len(data)
    print(f"  [weights] {offset / 1e6:.1f} MB ({cfg.n_params() / 1e6:.1f}M params)")

    param_structs = tuple(
        jax.ShapeDtypeStruct(tuple(s["shape"]), jnp.float32) for s in param_index
    )

    entries = []
    batches = [1] if fast else DECODE_BATCH_BUCKETS
    splits = DECODE_SPLITS
    prompts = [64] if fast else PREFILL_PROMPT_BUCKETS

    for b in batches:
        cache = jax.ShapeDtypeStruct(
            (cfg.n_layers, b, cfg.max_seq, cfg.n_heads_kv, cfg.head_dim),
            jnp.float32,
        )
        for s in splits:
            def fn(tokens, positions, kv_k, kv_v, *ps, _s=s):
                p = M.unflatten_params(cfg, list(ps))
                return M.decode_step(cfg, p, tokens, positions, kv_k, kv_v,
                                     num_splits=_s)

            args = (
                jax.ShapeDtypeStruct((b,), jnp.int32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
                cache, cache, *param_structs,
            )
            meta = {"preset": preset, "batch": b, "num_splits": s,
                    "max_seq": cfg.max_seq}
            entries.append(_lower_entry(
                f"model_decode_b{b}_s{s}", "decode", fn, args, meta, out_dir))

        for p_len in prompts:
            def fn(tokens, kv_lens, kv_k, kv_v, *ps):
                p = M.unflatten_params(cfg, list(ps))
                return M.prefill(cfg, p, tokens, kv_lens, kv_k, kv_v)

            args = (
                jax.ShapeDtypeStruct((b, p_len), jnp.int32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
                cache, cache, *param_structs,
            )
            meta = {"preset": preset, "batch": b, "prompt_len": p_len,
                    "max_seq": cfg.max_seq}
            entries.append(_lower_entry(
                f"model_prefill_b{b}_p{p_len}", "prefill", fn, args, meta,
                out_dir))

    model_block = {
        "preset": preset,
        "config": {
            "n_layers": cfg.n_layers, "d_model": cfg.d_model,
            "n_heads_q": cfg.n_heads_q, "n_heads_kv": cfg.n_heads_kv,
            "head_dim": cfg.head_dim, "ffn_dim": cfg.ffn_dim,
            "vocab": cfg.vocab, "max_seq": cfg.max_seq,
            "n_params": cfg.n_params(),
        },
        "weights": "weights.bin",
        "params": param_index,
    }
    return entries, model_block


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default=os.environ.get("FA3_MODEL_PRESET", "paper"),
                    choices=sorted(M.PRESETS))
    ap.add_argument("--fast", action="store_true",
                    help="small variant matrix for CI smoke runs")
    ap.add_argument("--skip-model", action="store_true")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()
    entries = []
    model_block = None
    if not args.skip_kernels:
        print("== kernel artifacts")
        entries += build_kernel_entries(args.out, fast=args.fast)
    if not args.skip_model:
        print(f"== model artifacts (preset={args.preset})")
        cfg = M.PRESETS[args.preset]
        m_entries, model_block = build_model_entries(
            cfg, args.preset, args.out, fast=args.fast)
        entries += m_entries

    manifest = {"version": MANIFEST_VERSION, "entries": entries}
    if model_block is not None:
        manifest["model"] = model_block
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"== wrote {len(entries)} artifacts to {args.out} "
          f"in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
