"""Split-KV flash decode attention as a Pallas kernel (Layer 1).

This is the compute hot-spot of the paper: decode-step attention
(L_Q = 1) over a KV cache, parallelized along the *sequence* dimension by a
``num_splits`` scheduling parameter — the knob the paper's sequence-aware
heuristic controls. Two kernels:

  1. ``_split_kernel``  — grid ``(B, H_KV, num_splits)``. Each grid program
     owns a contiguous slice of the KV cache and runs the streaming flash
     loop over kBlockN=128 chunks, producing an *unnormalized-then-locally-
     normalized* partial output plus its log-sum-exp (LSE).
  2. ``_combine_kernel`` — grid ``(B, H_KV)``. Reduces the ``num_splits``
     partials with the numerically-stable LSE-weighted combination (the
     "split-combine" step whose overhead the paper's conservative s = 3
     policy is balancing against occupancy).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): what FlashAttention-3
expresses with CTAs on H100 SMs, we express as a Pallas *grid dimension* —
each (b, h, split) program is the analog of one CTA, BlockSpec carves the
HBM→VMEM schedule the CUDA version did with thread blocks, and ``pack_gqa``
folds the H_Q/H_KV group into the query block so one program serves a whole
KV-head group (the memory-layout trick FA3's ``pack_gqa`` flag controls).

``interpret=True`` always: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute; interpret mode lowers to plain HLO so the
same artifact runs under the rust runtime. Scheduling *latency* on H100 is
modeled by ``rust/src/sim`` — this kernel is the *numerics* (and the HLO
that actually executes on the CPU backend).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_decode", "split_geometry", "KV_BLOCK"]

# KV-block granularity: kBlockN of the FA3 Hopper decode kernel. The FA3
# heuristic's nblk = ceil(L_K / 128) counts these blocks; the paper's guard
# fires on nblk == 4 (L_K in (384, 512]).
KV_BLOCK = 128

_NEG_INF = float("-inf")
_MASK_VAL = -1e30  # finite mask sentinel used inside the streaming loop


def split_geometry(l_k: int, num_splits: int, block_k: int = KV_BLOCK):
    """Static split geometry for a sequence of length ``l_k``.

    Returns ``(nblk, blocks_per_split, split_len, padded_len)`` where
    ``split_len = blocks_per_split * block_k`` is the per-program KV slice
    and ``padded_len = num_splits * split_len`` is what K/V are padded to.
    Over-splitting (``num_splits > nblk``) is legal — surplus programs see
    fully-masked slices and contribute LSE = -inf partials, exactly like
    FA3 CTAs that exit early. This path is exercised by the paper's Figure 3
    sweep up to s = 64 with nblk = 4.
    """
    if l_k < 1:
        raise ValueError(f"l_k must be >= 1, got {l_k}")
    if num_splits < 1:
        raise ValueError(f"num_splits must be >= 1, got {num_splits}")
    nblk = -(-l_k // block_k)
    blocks_per_split = -(-nblk // num_splits)
    split_len = blocks_per_split * block_k
    padded_len = num_splits * split_len
    return nblk, blocks_per_split, split_len, padded_len


def _split_kernel(
    q_ref,
    k_ref,
    v_ref,
    len_ref,
    o_ref,
    lse_ref,
    *,
    scale: float,
    split_len: int,
    block_k: int,
):
    """One (batch, kv-head, split) program: streaming flash over its slice."""
    sp = pl.program_id(2)
    kv_len = len_ref[0, 0]

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (g, D)
    g = q.shape[0]

    start = sp * split_len
    nchunks = split_len // block_k

    def body(i, carry):
        m, l, acc = carry
        k_blk = k_ref[0, pl.dslice(i * block_k, block_k), 0, :].astype(
            jnp.float32
        )  # (block_k, D)
        v_blk = v_ref[0, pl.dslice(i * block_k, block_k), 0, :].astype(
            jnp.float32
        )
        pos = start + i * block_k + jax.lax.iota(jnp.int32, block_k)
        valid = pos < kv_len  # (block_k,)

        s_ij = q @ k_blk.T  # (g, block_k)
        s_ij = jnp.where(valid[None, :], s_ij, _MASK_VAL)

        m_new = jnp.maximum(m, jnp.max(s_ij, axis=1))
        # alpha rescales the running accumulator; exp(_MASK_VAL - m) == 0
        # whenever anything valid has been seen, and exp(0) == 1 when both
        # are still at the sentinel (harmless: l and acc are then zero).
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s_ij - m_new[:, None]) * valid[None, :].astype(jnp.float32)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v_blk
        return m_new, l_new, acc_new

    m0 = jnp.full((g,), _MASK_VAL, dtype=jnp.float32)
    l0 = jnp.zeros((g,), dtype=jnp.float32)
    acc0 = jnp.zeros_like(q)
    m, l, acc = jax.lax.fori_loop(0, nchunks, body, (m0, l0, acc0))

    has_any = l > 0.0
    safe_l = jnp.where(has_any, l, 1.0)
    o_ref[0, 0, 0] = (acc / safe_l[:, None]).astype(o_ref.dtype)
    lse_ref[0, 0, 0] = jnp.where(has_any, m + jnp.log(safe_l), _NEG_INF)


def _combine_kernel(o_parts_ref, lse_ref, out_ref):
    """LSE-weighted combination of per-split partials for one (b, h)."""
    o_parts = o_parts_ref[0, 0].astype(jnp.float32)  # (s, g, D)
    lse = lse_ref[0, 0]  # (s, g), f32

    m_star = jnp.max(lse, axis=0)  # (g,)
    m_safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
    w = jnp.exp(lse - m_safe[None, :])  # (s, g); exp(-inf - c) == 0
    w = jnp.where(jnp.isfinite(lse), w, 0.0)
    denom = jnp.sum(w, axis=0)  # (g,)
    denom = jnp.where(denom > 0.0, denom, 1.0)
    out = jnp.einsum("sg,sgd->gd", w, o_parts) / denom[:, None]
    out_ref[0, 0] = out.astype(out_ref.dtype)


def flash_decode(
    q,
    k,
    v,
    kv_lens=None,
    *,
    num_splits: int = 1,
    block_k: int = KV_BLOCK,
    softmax_scale=None,
    pack_gqa: bool = True,
    interpret: bool = True,
):
    """Split-KV flash decode attention.

    Args:
      q: ``(B, H_Q, D)`` decode-step queries.
      k, v: ``(B, L_K, H_KV, D)`` KV cache (row-padded beyond ``kv_lens``).
      kv_lens: optional ``(B,)`` valid lengths (int32). ``None`` ⇒ full L_K.
      num_splits: sequence-split count ``s`` — the paper's control variable.
        Must be static (each value is a distinct compiled artifact, matching
        the precomputed-scheduler-metadata deployment path of §5.1).
      block_k: KV streaming block (kBlockN), default 128.
      softmax_scale: defaults to ``1/sqrt(D)``.
      pack_gqa: fold the query-head group into each program (FA3's layout
        flag). ``False`` runs one program per *query* head instead, i.e.
        grid ``(B, H_Q, s)`` with a singleton group — more programs, more
        partial traffic; the EA of §3 explores this knob.
      interpret: keep True (see module docstring).

    Returns:
      ``(B, H_Q, D)`` attention output in ``q.dtype``.
    """
    b, h_q, d = q.shape
    _, l_k, h_kv, dk = k.shape
    if v.shape != k.shape:
        raise ValueError(f"k/v shape mismatch: {k.shape} vs {v.shape}")
    if dk != d:
        raise ValueError(f"q/k head-dim mismatch: {d} vs {dk}")
    if h_q % h_kv != 0:
        raise ValueError(f"H_Q={h_q} not divisible by H_KV={h_kv}")
    if softmax_scale is None:
        softmax_scale = 1.0 / math.sqrt(d)

    if not pack_gqa:
        # One program per query head: replicate KV across the group and
        # reinterpret every query head as its own KV head.
        group = h_q // h_kv
        if group > 1:
            k = jnp.repeat(k, group, axis=2)
            v = jnp.repeat(v, group, axis=2)
        h_kv = h_q

    g = h_q // h_kv
    s = int(num_splits)
    _, _, split_len, padded_len = split_geometry(l_k, s, block_k)

    if kv_lens is None:
        kv_lens = jnp.full((b,), l_k, dtype=jnp.int32)
    kv_lens = kv_lens.astype(jnp.int32).reshape(b, 1)

    if padded_len > l_k:
        pad = [(0, 0), (0, padded_len - l_k), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)

    qg = q.reshape(b, h_kv, g, d)

    kernel = functools.partial(
        _split_kernel, scale=softmax_scale, split_len=split_len, block_k=block_k
    )
    o_parts, lse = pl.pallas_call(
        kernel,
        grid=(b, h_kv, s),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, split_len, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, split_len, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, si: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g, d), lambda bi, hi, si: (bi, hi, si, 0, 0)),
            pl.BlockSpec((1, 1, 1, g), lambda bi, hi, si: (bi, hi, si, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h_kv, s, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, h_kv, s, g), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, kv_lens)

    if s == 1:
        # No combine needed: the single partial is already normalized.
        out = o_parts[:, :, 0]  # (B, H_KV, g, D)
    else:
        out = pl.pallas_call(
            _combine_kernel,
            grid=(b, h_kv),
            in_specs=[
                pl.BlockSpec((1, 1, s, g, d), lambda bi, hi: (bi, hi, 0, 0, 0)),
                pl.BlockSpec((1, 1, s, g), lambda bi, hi: (bi, hi, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi: (bi, hi, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, h_kv, g, d), jnp.float32),
            interpret=interpret,
        )(o_parts, lse)

    return out.reshape(b, h_q, d).astype(q.dtype)
