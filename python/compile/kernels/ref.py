"""Pure-jnp oracle for split-KV flash decode attention.

This is the numerical ground truth the Pallas kernel (flash_decode.py) is
validated against in python/tests/test_kernel.py. It implements exactly the
semantics the kernel must honor:

  * decode-step attention: one query token per sequence (L_Q = 1),
  * grouped-query attention: H_Q query heads share H_KV key/value heads
    (group size g = H_Q // H_KV),
  * per-sequence KV lengths (``kv_lens``) for continuous batching: positions
    >= kv_lens[b] are masked out,
  * softmax computed in float32 regardless of input dtype.

No splitting happens here — split-KV is a scheduling decision, and the whole
point of the paper is that it must not change the math. The oracle is the
s-independent answer every split count must reproduce.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_decode_ref"]


def attention_decode_ref(q, k, v, kv_lens=None, softmax_scale=None):
    """Reference decode attention.

    Args:
      q: ``(B, H_Q, D)`` query for the single decode token.
      k: ``(B, L_K, H_KV, D)`` key cache (possibly padded beyond kv_lens).
      v: ``(B, L_K, H_KV, D)`` value cache.
      kv_lens: optional ``(B,)`` int32 valid lengths; ``None`` means all of
        ``L_K`` is valid for every sequence.
      softmax_scale: optional scale; defaults to ``1/sqrt(D)``.

    Returns:
      ``(B, H_Q, D)`` attention output in ``q.dtype``.
    """
    b, h_q, d = q.shape
    _, l_k, h_kv, _ = k.shape
    if h_q % h_kv != 0:
        raise ValueError(f"H_Q={h_q} not divisible by H_KV={h_kv}")
    g = h_q // h_kv
    if softmax_scale is None:
        softmax_scale = 1.0 / (d**0.5)

    qf = q.astype(jnp.float32).reshape(b, h_kv, g, d)
    kf = k.astype(jnp.float32)  # (B, L, H_KV, D)
    vf = v.astype(jnp.float32)

    # scores: (B, H_KV, g, L)
    scores = jnp.einsum("bhgd,blhd->bhgl", qf, kf) * softmax_scale

    valid = None
    if kv_lens is not None:
        pos = jnp.arange(l_k, dtype=jnp.int32)
        valid = pos[None, :] < kv_lens.astype(jnp.int32)[:, None]  # (B, L)
        scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)

    # Numerically stable softmax in f32.
    m = jnp.max(scores, axis=-1, keepdims=True)
    # Guard fully-masked rows (kv_len == 0): max is -inf, exp -> nan otherwise.
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(scores - m)
    if valid is not None:
        p = jnp.where(valid[:, None, None, :], p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    probs = p / denom

    out = jnp.einsum("bhgl,blhd->bhgd", probs, vf)
    return out.reshape(b, h_q, d).astype(q.dtype)
