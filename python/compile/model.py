"""Layer 2: GQA transformer decode model (build-time JAX).

A Llama-style decoder with grouped-query attention whose decode step calls
the Layer-1 Pallas split-KV kernel (kernels/flash_decode.py), so the
``num_splits`` scheduling decision made by the rust coordinator is baked
into each AOT artifact exactly like the precomputed-scheduler-metadata path
of the paper's §5.1 (vLLM-style: the split count is decided *before* launch
and passed explicitly).

The paper's testbed model is Llama-3.1-70B-Instruct under 8-way tensor
parallelism, which gives each device H_Q = 8, H_KV = 1, D = 128 — pure-MQA
shape. Real 70B weights are neither available nor relevant to the
scheduling contribution (DESIGN.md §Substitutions), so we serve a
synthetic-weight model with the same per-device attention geometry.

Presets:
  * ``paper``  — H_Q=8, H_KV=1, D=128, d_model=1024, 4 layers (~52M params):
                 the per-device shape of Llama-70B/TP-8.
  * ``small``  — H_Q=8, H_KV=1, D=64, d_model=512, 2 layers (~10M params):
                 fast CI / test preset, same low-head-count regime.
  * ``gqa2``   — H_Q=8, H_KV=2, D=128: the H_KV=2 rows of Table 1.

Everything here runs ONCE at ``make artifacts`` (aot.py); Python is never
on the request path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.flash_decode import flash_decode

__all__ = [
    "ModelConfig",
    "PRESETS",
    "param_specs",
    "init_params",
    "flatten_params",
    "unflatten_params",
    "decode_step",
    "prefill",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyperparameters (all shapes are compile-time)."""

    n_layers: int = 4
    d_model: int = 1024
    n_heads_q: int = 8
    n_heads_kv: int = 1
    head_dim: int = 128
    ffn_dim: int = 2816
    vocab: int = 4096
    max_seq: int = 1024
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5

    def __post_init__(self):
        if self.n_heads_q % self.n_heads_kv != 0:
            raise ValueError("n_heads_q must be divisible by n_heads_kv")
        if self.n_heads_q * self.head_dim != self.d_model:
            # Not fatal (Llama allows it via proj), but we keep q_dim == d_model
            # so W_O is square; enforce for simplicity.
            raise ValueError("n_heads_q * head_dim must equal d_model")

    @property
    def q_dim(self) -> int:
        return self.n_heads_q * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_heads_kv * self.head_dim

    def n_params(self) -> int:
        per_layer = (
            self.d_model * self.q_dim
            + 2 * self.d_model * self.kv_dim
            + self.q_dim * self.d_model
            + 3 * self.d_model * self.ffn_dim
            + 2 * self.d_model
        )
        return (
            self.n_layers * per_layer
            + 2 * self.vocab * self.d_model
            + self.d_model
        )


PRESETS: Dict[str, ModelConfig] = {
    "paper": ModelConfig(),
    "small": ModelConfig(
        n_layers=2, d_model=512, n_heads_q=8, n_heads_kv=1, head_dim=64,
        ffn_dim=1408, vocab=4096, max_seq=1024,
    ),
    "gqa2": ModelConfig(
        n_layers=4, d_model=1024, n_heads_q=8, n_heads_kv=2, head_dim=128,
        ffn_dim=2816, vocab=4096, max_seq=1024,
    ),
}


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) ordering of all parameters.

    This ordering is the ABI between aot.py (which writes weights.bin and
    the manifest) and the rust runtime (which feeds parameters positionally
    after the dynamic inputs). Keep it stable.
    """
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.attn_norm", (cfg.d_model,)),
            (f"l{i}.wq", (cfg.d_model, cfg.q_dim)),
            (f"l{i}.wk", (cfg.d_model, cfg.kv_dim)),
            (f"l{i}.wv", (cfg.d_model, cfg.kv_dim)),
            (f"l{i}.wo", (cfg.q_dim, cfg.d_model)),
            (f"l{i}.ffn_norm", (cfg.d_model,)),
            (f"l{i}.w_gate", (cfg.d_model, cfg.ffn_dim)),
            (f"l{i}.w_up", (cfg.d_model, cfg.ffn_dim)),
            (f"l{i}.w_down", (cfg.ffn_dim, cfg.d_model)),
        ]
    specs += [
        ("out_norm", (cfg.d_model,)),
        ("w_out", (cfg.d_model, cfg.vocab)),
    ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Synthetic weights: scaled-gaussian init (numpy RNG for determinism)."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_specs(cfg):
        if name.endswith("norm"):
            arr = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else cfg.d_model
            arr = rng.standard_normal(shape).astype(np.float32) / np.sqrt(fan_in)
        params[name] = jnp.asarray(arr)
    return params


def flatten_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray]):
    return [params[name] for name, _ in param_specs(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> Dict[str, jnp.ndarray]:
    names = [name for name, _ in param_specs(cfg)]
    if len(flat) != len(names):
        raise ValueError(f"expected {len(names)} params, got {len(flat)}")
    return dict(zip(names, flat))


def _rms_norm(x, w, eps):
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * w


def _rope(x, positions, theta):
    """Rotary embedding. x: (B, H, D) or (B, T, H, D); positions: (B,) or (B, T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    # Broadcast over the head axis, which sits between positions and freq.
    if x.ndim == 3:  # (B, H, D), positions (B,)
        angles = angles[:, None, :]  # (B, 1, half)
    else:  # (B, T, H, D), positions (B, T)
        angles = angles[:, :, None, :]  # (B, T, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rot.astype(x.dtype)


def _ffn(x, p, i):
    gate = jax.nn.silu(x @ p[f"l{i}.w_gate"])
    up = x @ p[f"l{i}.w_up"]
    return (gate * up) @ p[f"l{i}.w_down"]


def decode_step(
    cfg: ModelConfig,
    params: Dict[str, jnp.ndarray],
    tokens,      # (B,) int32 — token to decode at this step
    positions,   # (B,) int32 — cache slot to write (== current kv_len)
    kv_k,        # (L, B, max_seq, H_KV, D) f32
    kv_v,        # (L, B, max_seq, H_KV, D) f32
    *,
    num_splits: int = 1,
):
    """One decode step. Returns (logits, kv_k, kv_v).

    Attention runs over ``positions + 1`` valid cache entries (the new
    token's K/V are written before attending), through the L1 split-KV
    Pallas kernel with the statically-chosen ``num_splits``.
    """
    b = tokens.shape[0]
    x = params["embed"][tokens]  # (B, d_model)
    kv_lens = positions.astype(jnp.int32) + 1
    batch_idx = jnp.arange(b)

    for i in range(cfg.n_layers):
        h = _rms_norm(x, params[f"l{i}.attn_norm"], cfg.norm_eps)
        q = (h @ params[f"l{i}.wq"]).reshape(b, cfg.n_heads_q, cfg.head_dim)
        kn = (h @ params[f"l{i}.wk"]).reshape(b, cfg.n_heads_kv, cfg.head_dim)
        vn = (h @ params[f"l{i}.wv"]).reshape(b, cfg.n_heads_kv, cfg.head_dim)
        q = _rope(q, positions, cfg.rope_theta)
        kn = _rope(kn, positions, cfg.rope_theta)

        kv_k = kv_k.at[i, batch_idx, positions].set(kn)
        kv_v = kv_v.at[i, batch_idx, positions].set(vn)

        attn = flash_decode(
            q, kv_k[i], kv_v[i], kv_lens, num_splits=num_splits
        )  # (B, H_Q, D)
        x = x + attn.reshape(b, cfg.q_dim) @ params[f"l{i}.wo"]

        h = _rms_norm(x, params[f"l{i}.ffn_norm"], cfg.norm_eps)
        x = x + _ffn(h, params, i)

    x = _rms_norm(x, params["out_norm"], cfg.norm_eps)
    logits = x @ params["w_out"]  # (B, vocab)
    return logits, kv_k, kv_v


def prefill(
    cfg: ModelConfig,
    params: Dict[str, jnp.ndarray],
    tokens,     # (B, P) int32 — prompt tokens, right-padded
    kv_lens,    # (B,) int32 — true prompt lengths (<= P)
    kv_k,       # (L, B, max_seq, H_KV, D)
    kv_v,
):
    """Prompt ingestion: full causal attention over the prompt window.

    The paper's contribution is decode-only, so prefill uses a plain jnp
    causal attention (no splitting — prefill has L_Q = P parallelism and is
    never in the low-occupancy regime the paper targets). Writes K/V for
    the first P cache slots and returns the last *valid* token's logits.
    """
    b, p_len = tokens.shape
    x = params["embed"][tokens]  # (B, P, d_model)
    positions = jnp.broadcast_to(jnp.arange(p_len, dtype=jnp.int32), (b, p_len))
    pos_f = jnp.arange(p_len)
    causal = pos_f[None, :] <= pos_f[:, None]  # (P, P) keys <= query pos
    pad_ok = pos_f[None, :] < kv_lens.astype(jnp.int32)[:, None]  # (B, P)
    mask = causal[None, :, :] & pad_ok[:, None, :]  # (B, P, P)
    scale = 1.0 / float(np.sqrt(cfg.head_dim))
    group = cfg.n_heads_q // cfg.n_heads_kv

    for i in range(cfg.n_layers):
        h = _rms_norm(x, params[f"l{i}.attn_norm"], cfg.norm_eps)
        q = (h @ params[f"l{i}.wq"]).reshape(b, p_len, cfg.n_heads_q, cfg.head_dim)
        kn = (h @ params[f"l{i}.wk"]).reshape(b, p_len, cfg.n_heads_kv, cfg.head_dim)
        vn = (h @ params[f"l{i}.wv"]).reshape(b, p_len, cfg.n_heads_kv, cfg.head_dim)
        q = _rope(q, positions, cfg.rope_theta)
        kn = _rope(kn, positions, cfg.rope_theta)

        kv_k = kv_k.at[i, :, :p_len].set(kn)
        kv_v = kv_v.at[i, :, :p_len].set(vn)

        qg = q.reshape(b, p_len, cfg.n_heads_kv, group, cfg.head_dim)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                            kn.astype(jnp.float32)) * scale
        scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
        m = jnp.max(scores, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        pr = jnp.exp(scores - m)
        pr = jnp.where(mask[:, None, None, :, :], pr, 0.0)
        denom = jnp.sum(pr, axis=-1, keepdims=True)
        denom = jnp.where(denom == 0.0, 1.0, denom)
        attn = jnp.einsum("bhgqk,bkhd->bqhgd", pr / denom,
                          vn.astype(jnp.float32)).astype(x.dtype)
        attn = attn.reshape(b, p_len, cfg.q_dim)
        x = x + attn @ params[f"l{i}.wo"]

        h = _rms_norm(x, params[f"l{i}.ffn_norm"], cfg.norm_eps)
        x = x + _ffn(h, params, i)

    x = _rms_norm(x, params["out_norm"], cfg.norm_eps)
    # Gather each sequence's last valid position.
    last = jnp.clip(kv_lens.astype(jnp.int32) - 1, 0, p_len - 1)
    x_last = x[jnp.arange(b), last]  # (B, d_model)
    logits = x_last @ params["w_out"]
    return logits, kv_k, kv_v
